//! `artifacts/manifest.json` schema — the contract with
//! `python/compile/aot.py` (version 1).  Parsed with the in-tree JSON
//! parser (`util::json`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context};

use crate::util::Json;
use crate::Result;

/// One exported parameter tensor inside a model weight blob.
#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in *elements* (f32) into the weight blob.
    pub offset: usize,
    pub numel: usize,
}

/// Tensor spec (shape + dtype).
#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(TensorMeta {
            shape: v.get("shape")?.as_usize_vec()?,
            dtype: v
                .opt("dtype")
                .map(|d| d.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| "f32".to_string()),
        })
    }
}

/// Golden input/output blob for integration tests.
#[derive(Debug, Clone)]
pub struct GoldenMeta {
    pub file: String,
    pub input_numel: usize,
    pub output_numel: usize,
    pub output_l2: f64,
    pub output_first8: Vec<f64>,
}

/// One AOT artifact (HLO + weights + IO spec).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub model: String,
    pub batch: usize,
    pub conv_impl: String,
    pub hlo: String,
    pub hlo_sha256: String,
    pub weights: String,
    pub params: Vec<ParamMeta>,
    /// The HLO takes the whole weight blob as ONE flat argument and
    /// slices each tensor device-side (aot.py `packed=True`), so the
    /// engine uploads exactly one buffer per model instead of one per
    /// parameter tensor.
    pub packed_weights: bool,
    pub input: TensorMeta,
    pub output: TensorMeta,
    pub golden: Option<GoldenMeta>,
}

impl ArtifactMeta {
    fn from_json(v: &Json) -> Result<Self> {
        let params = v
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamMeta {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p.get("shape")?.as_usize_vec()?,
                    offset: p.get("offset")?.as_usize()?,
                    numel: p.get("numel")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let golden = match v.opt("golden") {
            None => None,
            Some(g) => Some(GoldenMeta {
                file: g.get("file")?.as_str()?.to_string(),
                input_numel: g.get("input_numel")?.as_usize()?,
                output_numel: g.get("output_numel")?.as_usize()?,
                output_l2: g.get("output_l2")?.as_f64()?,
                output_first8: g
                    .get("output_first8")?
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_f64())
                    .collect::<Result<Vec<_>>>()?,
            }),
        };
        let packed_weights = match v.opt("packed_weights") {
            None => false,
            Some(p) => p.as_bool()?,
        };
        Ok(ArtifactMeta {
            name: v.get("name")?.as_str()?.to_string(),
            model: v.get("model")?.as_str()?.to_string(),
            batch: v.get("batch")?.as_usize()?,
            conv_impl: v.get("conv_impl")?.as_str()?.to_string(),
            hlo: v.get("hlo")?.as_str()?.to_string(),
            hlo_sha256: v.get("hlo_sha256")?.as_str()?.to_string(),
            weights: v.get("weights")?.as_str()?.to_string(),
            params,
            packed_weights,
            input: TensorMeta::from_json(v.get("input")?)?,
            output: TensorMeta::from_json(v.get("output")?)?,
            golden,
        })
    }
}

/// Accounting row exported per layer by the python side.
#[derive(Debug, Clone)]
pub struct ManifestLayer {
    pub name: String,
    pub kind: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub macs: u64,
    pub params: u64,
    pub ops: u64,
}

/// Per-model accounting (the cross-check contract with `models`).
#[derive(Debug, Clone)]
pub struct ModelAccounting {
    pub in_shape: Vec<usize>,
    pub layers: Vec<ManifestLayer>,
    pub total_macs: u64,
    pub total_params: u64,
}

/// One model's weight blob plus per-tensor views into it.
///
/// The blob is decoded (and, under PJRT, uploaded) exactly once per
/// model; every parameter tensor is an `(offset, numel)` window over
/// it — the host never materialises a per-tensor copy.  This is the
/// CPU-side mirror of the packed-weights device contract
/// ([`ArtifactMeta::packed_weights`]).
#[derive(Debug, Clone)]
pub struct WeightViews {
    blob: Arc<[f32]>,
    views: Vec<(usize, usize)>,
}

impl WeightViews {
    /// Wrap a decoded blob; validates that every parameter window is
    /// in bounds (a truncated blob fails here, not at execute time).
    pub fn from_blob(
        blob: Arc<[f32]>,
        params: &[ParamMeta],
    ) -> Result<Self> {
        let mut views = Vec::with_capacity(params.len());
        for p in params {
            let end = p.offset.checked_add(p.numel).ok_or_else(|| {
                anyhow!("param {}: offset overflow", p.name)
            })?;
            if end > blob.len() {
                return Err(anyhow!(
                    "param {}: window {}..{end} outside blob of {} floats",
                    p.name,
                    p.offset,
                    blob.len()
                ));
            }
            views.push((p.offset, p.numel));
        }
        Ok(WeightViews { blob, views })
    }

    /// The shared backing blob.
    pub fn blob(&self) -> &Arc<[f32]> {
        &self.blob
    }

    /// Number of parameter tensors.
    pub fn len(&self) -> usize {
        self.views.len()
    }

    pub fn is_empty(&self) -> bool {
        self.views.is_empty()
    }

    /// The `i`-th parameter tensor as a zero-copy slice of the blob.
    pub fn view(&self, i: usize) -> &[f32] {
        let (off, n) = self.views[i];
        &self.blob[off..off + n]
    }

    /// All tensors, in argument order.
    pub fn iter(&self) -> impl Iterator<Item = &[f32]> {
        self.views.iter().map(|&(off, n)| &self.blob[off..off + n])
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u64,
    pub seed: u64,
    pub artifacts: Vec<ArtifactMeta>,
    pub models: HashMap<String, ModelAccounting>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let v = Json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        let version = v.get("version")?.as_u64()?;
        if version != 1 {
            return Err(anyhow!("unsupported manifest version {version}"));
        }
        let artifacts = v
            .get("artifacts")?
            .as_arr()?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut models = HashMap::new();
        for (name, mv) in v.get("models")?.as_obj()? {
            let layers = mv
                .get("layers")?
                .as_arr()?
                .iter()
                .map(|l| {
                    Ok(ManifestLayer {
                        name: l.get("name")?.as_str()?.to_string(),
                        kind: l.get("kind")?.as_str()?.to_string(),
                        in_shape: l.get("in_shape")?.as_usize_vec()?,
                        out_shape: l.get("out_shape")?.as_usize_vec()?,
                        macs: l.get("macs")?.as_u64()?,
                        params: l.get("params")?.as_u64()?,
                        ops: l.get("ops")?.as_u64()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelAccounting {
                    in_shape: mv.get("in_shape")?.as_usize_vec()?,
                    layers,
                    total_macs: mv.get("total_macs")?.as_u64()?,
                    total_params: mv.get("total_params")?.as_u64()?,
                },
            );
        }
        Ok(Manifest {
            version,
            seed: v.get("seed")?.as_u64()?,
            artifacts,
            models,
            dir: dir.to_path_buf(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "artifact {name:?} not in manifest (have: {:?})",
                    self.artifacts.iter().map(|a| &a.name).collect::<Vec<_>>()
                )
            })
    }

    /// Absolute path of a file referenced by the manifest.
    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Read a model's weight blob (f32 little-endian) into a shared
    /// buffer.  Callers (the engines) cache the `Arc` per model, so
    /// every artifact of a model shares one host-side copy — the blob
    /// is decoded exactly once and never cloned again.
    pub fn read_weights(&self, art: &ArtifactMeta) -> Result<Arc<[f32]>> {
        let path = self.path_of(&art.weights);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let values = bytes_to_f32(&bytes)
            .with_context(|| format!("decoding {}", path.display()))?;
        Ok(values.into())
    }

    /// Read a model's weight blob and wrap it in per-tensor views
    /// (decode once, slice everywhere — see [`WeightViews`]).
    pub fn read_weight_views(
        &self,
        art: &ArtifactMeta,
    ) -> Result<WeightViews> {
        let blob = self.read_weights(art)?;
        WeightViews::from_blob(blob, &art.params)
            .with_context(|| format!("weight views for {}", art.name))
    }

    /// Read a golden blob: (input, expected_output).
    pub fn read_golden(
        &self,
        art: &ArtifactMeta,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let g = art
            .golden
            .as_ref()
            .ok_or_else(|| anyhow!("{} has no golden blob", art.name))?;
        let bytes = std::fs::read(self.path_of(&g.file))?;
        let all = bytes_to_f32(&bytes)
            .with_context(|| format!("decoding {}", g.file))?;
        if all.len() != g.input_numel + g.output_numel {
            return Err(anyhow!(
                "golden blob size mismatch: {} != {}+{}",
                all.len(),
                g.input_numel,
                g.output_numel
            ));
        }
        let (i, o) = all.split_at(g.input_numel);
        Ok((i.to_vec(), o.to_vec()))
    }
}

/// Little-endian byte buffer to f32 vector.  A length that is not a
/// multiple of 4 is a corrupt blob and returns an error instead of
/// silently truncating the tail.  Valid input decodes through
/// [`crate::util::vecops::bytes_to_f32_wide`]: an alignment-checked
/// reinterpret-in-place fast path (one wide copy on little-endian
/// targets) with a bit-identical `from_le_bytes` fallback for
/// misaligned views.
pub fn bytes_to_f32(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        return Err(anyhow!(
            "f32 blob length {} is not a multiple of 4 ({} trailing bytes)",
            bytes.len(),
            bytes.len() % 4
        ));
    }
    Ok(crate::util::vecops::bytes_to_f32_wide(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_artifacts_dir;

    fn manifest_or_skip() -> Option<Manifest> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Manifest::load(&dir).unwrap())
    }

    #[test]
    fn bytes_to_f32_roundtrip() {
        let vals = [1.0f32, -2.5, 3.25e-3];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(bytes_to_f32(&bytes).unwrap(), vals);
        assert_eq!(bytes_to_f32(&[]).unwrap(), Vec::<f32>::new());
    }

    #[test]
    fn bytes_to_f32_rejects_trailing_bytes() {
        let err = bytes_to_f32(&[0, 0, 0, 0, 7]).unwrap_err();
        assert!(
            err.to_string().contains("not a multiple of 4"),
            "{err}"
        );
        assert!(err.to_string().contains("1 trailing"), "{err}");
    }

    #[test]
    fn bytes_to_f32_aligned_and_misaligned_views_agree() {
        // Regression for the wide fast path: decoding an aligned
        // blob and a deliberately misaligned view of the same
        // payload must both succeed and agree bit-for-bit, whichever
        // internal branch each takes.
        let vals: Vec<f32> = (0..64).map(|i| i as f32 * 0.37 - 9.5).collect();
        let payload: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        // Pad on the left so at least one of the four offsets is
        // guaranteed misaligned relative to a 4-byte boundary.
        let mut padded = vec![0u8; 4];
        padded.extend_from_slice(&payload);
        for off in 0..4usize {
            let view = &padded[off..off + payload.len()];
            let got = bytes_to_f32(view).unwrap();
            let want: Vec<f32> = view
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "offset {off}");
            }
        }
    }

    fn pm(name: &str, offset: usize, numel: usize) -> ParamMeta {
        ParamMeta {
            name: name.into(),
            shape: vec![numel],
            offset,
            numel,
        }
    }

    #[test]
    fn weight_views_slice_without_copying() {
        let blob: Arc<[f32]> = (0..10).map(|i| i as f32).collect();
        let views = WeightViews::from_blob(
            blob.clone(),
            &[pm("a", 0, 4), pm("b", 4, 6)],
        )
        .unwrap();
        assert_eq!(views.len(), 2);
        assert_eq!(views.view(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(views.view(1), &[4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        // Zero copy: the views alias the blob's allocation.
        assert!(std::ptr::eq(
            views.view(0).as_ptr(),
            views.blob().as_ptr()
        ));
        assert_eq!(
            views.iter().map(|v| v.len()).sum::<usize>(),
            blob.len()
        );
    }

    #[test]
    fn weight_views_reject_out_of_bounds_params() {
        let blob: Arc<[f32]> = vec![0.0f32; 8].into();
        let err = WeightViews::from_blob(blob, &[pm("w", 4, 5)])
            .unwrap_err();
        assert!(err.to_string().contains("outside blob"), "{err}");
    }

    #[test]
    fn packed_weights_flag_parses_and_defaults_off() {
        let base = r#"{
            "name": "m_b1_jnp", "model": "m", "batch": 1,
            "conv_impl": "jnp", "hlo": "m.hlo.txt", "hlo_sha256": "x",
            "weights": "m.weights.bin",
            "params": [{"name": "w", "shape": [2], "offset": 0, "numel": 2}],
            "input": {"shape": [1, 2]}, "output": {"shape": [1, 2]}
        }"#;
        let a = ArtifactMeta::from_json(&Json::parse(base).unwrap())
            .unwrap();
        assert!(!a.packed_weights, "flag must default off");
        let packed = base.replacen(
            "\"batch\": 1,",
            "\"batch\": 1, \"packed_weights\": true,",
            1,
        );
        let b = ArtifactMeta::from_json(&Json::parse(&packed).unwrap())
            .unwrap();
        assert!(b.packed_weights);
    }

    #[test]
    fn manifest_loads_and_has_expected_artifacts() {
        let Some(m) = manifest_or_skip() else { return };
        assert!(m.artifact("tinynet_b1_pallas").is_ok());
        assert!(m.artifact("alexnet_b1_jnp").is_ok());
        assert!(m.artifact("missing").is_err());
    }

    #[test]
    fn param_offsets_contiguous() {
        let Some(m) = manifest_or_skip() else { return };
        for a in &m.artifacts {
            let mut expect = 0usize;
            for p in &a.params {
                assert_eq!(p.offset, expect, "{}::{}", a.name, p.name);
                assert_eq!(p.numel, p.shape.iter().product::<usize>());
                expect += p.numel;
            }
        }
    }

    #[test]
    fn weights_blob_matches_param_totals() {
        let Some(m) = manifest_or_skip() else { return };
        let a = m.artifact("tinynet_b1_pallas").unwrap();
        let w = m.read_weights(a).unwrap();
        let total: usize = a.params.iter().map(|p| p.numel).sum();
        assert_eq!(w.len(), total);
    }

    #[test]
    fn golden_blob_consistent_with_meta() {
        let Some(m) = manifest_or_skip() else { return };
        let a = m.artifact("tinynet_b1_pallas").unwrap();
        let (input, output) = m.read_golden(a).unwrap();
        let g = a.golden.as_ref().unwrap();
        assert_eq!(input.len(), g.input_numel);
        assert_eq!(output.len(), g.output_numel);
        let l2 =
            output.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        assert!((l2 - g.output_l2).abs() / g.output_l2 < 1e-4);
    }

    /// The cross-check contract: rust model IR accounting must equal
    /// the python-side manifest accounting, row by row.
    #[test]
    fn rust_accounting_matches_python_manifest() {
        let Some(m) = manifest_or_skip() else { return };
        assert!(!m.models.is_empty());
        for (name, acct) in &m.models {
            let Some(model) = crate::models::by_name(name) else {
                panic!("manifest model {name} unknown to rust IR");
            };
            let infos = model.propagate();
            assert_eq!(
                model.total_macs(),
                acct.total_macs,
                "{name}: total MACs mismatch"
            );
            assert_eq!(
                model.total_params(),
                acct.total_params,
                "{name}: total params mismatch"
            );
            // Row-level check on conv/fc rows.
            let py: HashMap<&str, &ManifestLayer> = acct
                .layers
                .iter()
                .map(|l| (l.name.as_str(), l))
                .collect();
            for info in infos
                .iter()
                .filter(|i| i.kind == "conv" || i.kind == "fc")
            {
                let Some(pl) = py.get(info.name.as_str()) else {
                    panic!("{name}: layer {} missing in manifest", info.name)
                };
                assert_eq!(info.macs, pl.macs, "{name}.{}", info.name);
                assert_eq!(info.params, pl.params, "{name}.{}", info.name);
                assert_eq!(
                    info.out_shape.dims(),
                    pl.out_shape,
                    "{name}.{}",
                    info.name
                );
            }
        }
    }
}
