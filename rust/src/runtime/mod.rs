//! Runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the rust hot path.
//!
//! Two interchangeable engines sit behind the same `Engine` API:
//!
//! - **`pjrt` feature on** — `engine`: the real PJRT/XLA CPU client
//!   (requires the XLA toolchain's `xla` bindings crate; see
//!   Cargo.toml).  The interchange format is **HLO text** — jax ≥ 0.5
//!   serialized protos carry 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//!   /opt/xla-example/README.md).
//! - **`pjrt` feature off (default)** — `cpu_ref`: a deterministic
//!   CPU reference executor.  It loads the same manifest and weight
//!   blobs and produces shape-correct, batch-invariant pseudo-logits,
//!   so the whole coordinator stack (boards, batcher, router,
//!   service) builds and serves without an XLA toolchain.  Numerics
//!   golden tests are gated on the `pjrt` feature.
//!
//! `Engine` owns per-model state and is deliberately **not** `Send`
//! (the PJRT wrappers hold raw pointers): the coordinator gives each
//! simulated board its own engine thread (`coordinator::board`).
//!
//! Hot-path design: model weights are decoded from the blob once per
//! model into shared [`WeightViews`] (zero-copy per-tensor windows
//! over one `Arc<[f32]>`), and every request only moves its input
//! batch — no weight copies on the request path.  Artifacts exported
//! with `aot.py` packed mode (`packed_weights` in the manifest) take
//! the whole blob as ONE device argument sliced inside the graph, so
//! the PJRT engine uploads a single buffer per model — the warm-up
//! win on 200+-tensor models like ResNet-50.

#[cfg(not(feature = "pjrt"))]
mod cpu_ref;
#[cfg(feature = "pjrt")]
mod engine;
mod manifest;

/// Cumulative execution statistics (perf pass instrumentation).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub executions: u64,
    /// Time uploading input literals/buffers, µs.
    pub upload_us: u64,
    /// Time inside execute, µs.
    pub execute_us: u64,
    /// Time downloading outputs, µs.
    pub download_us: u64,
    /// One-time compile/load time, µs.
    pub compile_us: u64,
}

#[cfg(not(feature = "pjrt"))]
pub use cpu_ref::Engine;
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use manifest::{
    bytes_to_f32, ArtifactMeta, GoldenMeta, Manifest, ManifestLayer,
    ModelAccounting, ParamMeta, WeightViews,
};
