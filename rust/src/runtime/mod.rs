//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them on the CPU PJRT client from the rust hot path.
//!
//! The interchange format is **HLO text** — jax ≥ 0.5 serialized protos
//! carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! [`Engine`] owns a `PjRtClient` and is deliberately **not** `Send`
//! (the crate's PJRT wrappers hold raw pointers): the coordinator gives
//! each simulated board its own engine thread (`coordinator::board`).
//!
//! Hot-path design: model weights are uploaded to device buffers once
//! per model (`PjRtBuffer`), and every request only uploads its input
//! batch — `execute_b` then runs with zero weight copies.

mod engine;
mod manifest;

pub use engine::{Engine, ExecStats};
pub use manifest::{
    ArtifactMeta, GoldenMeta, Manifest, ManifestLayer, ModelAccounting,
    ParamMeta,
};
