//! The PJRT execution engine (adapted from /opt/xla-example/load_hlo).
//!
//! Lifecycle per artifact:
//! 1. `HloModuleProto::from_text_file` — parse the HLO text;
//! 2. `client.compile` — JIT once, cached;
//! 3. weights → `PjRtBuffer`s once per *model* (shared by all batch
//!    variants of that model);
//! 4. per request: upload the input batch, `execute_b`, download logits.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Context};

use super::manifest::{ArtifactMeta, Manifest};
use super::ExecStats;
use crate::Result;

struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
    /// Device-resident weight buffers, argument order.  One buffer per
    /// parameter tensor — or a single packed blob buffer when the
    /// artifact's HLO view-slices tensors device-side
    /// (`ArtifactMeta::packed_weights`).
    weights: Rc<Vec<xla::PjRtBuffer>>,
}

/// Cache key of a model's device-resident weights: packed and
/// per-tensor layouts are distinct uploads.
type WeightKey = (String, bool);

/// Single-threaded PJRT engine (deliberately `!Send`; see module docs).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    loaded: RefCell<HashMap<String, Rc<LoadedArtifact>>>,
    /// Weight buffers shared across artifacts of the same model.
    model_weights: RefCell<HashMap<WeightKey, Rc<Vec<xla::PjRtBuffer>>>>,
    stats: RefCell<ExecStats>,
}

impl Engine {
    /// Open an artifact directory (`make artifacts` output).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        Ok(Engine {
            client,
            manifest,
            loaded: RefCell::new(HashMap::new()),
            model_weights: RefCell::new(HashMap::new()),
            stats: RefCell::new(ExecStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    /// Upload a model's weights once, returning device buffers.
    ///
    /// The host-side blob is decoded once by the manifest and wrapped
    /// in zero-copy per-tensor views.  For `packed_weights` artifacts
    /// the *whole blob* uploads as ONE device buffer (the compiled HLO
    /// view-slices each tensor device-side), so warm-up on a
    /// 200+-tensor model costs one transfer instead of hundreds; the
    /// per-tensor layout remains for legacy artifacts, uploading
    /// straight from the shared views without intermediate clones.
    fn weights_for(&self, art: &ArtifactMeta) -> Result<Rc<Vec<xla::PjRtBuffer>>> {
        let key: WeightKey = (art.model.clone(), art.packed_weights);
        if let Some(w) = self.model_weights.borrow().get(&key) {
            return Ok(w.clone());
        }
        let views = self.manifest.read_weight_views(art)?;
        let bufs = if art.packed_weights {
            let blob = views.blob();
            let shape = [blob.len()];
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(blob, &shape, None)
                .map_err(|e| {
                    anyhow!("uploading packed blob for {}: {e}", art.model)
                })?;
            vec![buf]
        } else {
            let mut bufs = Vec::with_capacity(art.params.len());
            for (i, p) in art.params.iter().enumerate() {
                let buf = self
                    .client
                    .buffer_from_host_buffer::<f32>(
                        views.view(i),
                        &p.shape,
                        None,
                    )
                    .map_err(|e| anyhow!("uploading {}: {e}", p.name))?;
                bufs.push(buf);
            }
            bufs
        };
        let rc = Rc::new(bufs);
        self.model_weights.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Pre-compile an artifact and upload its weights (warm the cache).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.load(name).map(|_| ())
    }

    /// Compile (once) and cache an artifact.
    fn load(&self, name: &str) -> Result<Rc<LoadedArtifact>> {
        if let Some(l) = self.loaded.borrow().get(name) {
            return Ok(l.clone());
        }
        let meta = self.manifest.artifact(name)?.clone();
        let t0 = Instant::now();
        let hlo_path = self.manifest.path_of(&meta.hlo);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        let weights = self
            .weights_for(&meta)
            .with_context(|| format!("weights for {name}"))?;
        self.stats.borrow_mut().compile_us +=
            t0.elapsed().as_micros() as u64;
        let loaded = Rc::new(LoadedArtifact { exe, meta, weights });
        self.loaded
            .borrow_mut()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Execute an artifact on an input batch; returns flat f32 logits.
    pub fn execute(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        let loaded = self.load(name)?;
        let meta = &loaded.meta;
        if input.len() != meta.input.numel() {
            return Err(anyhow!(
                "{name}: input has {} elements, artifact wants {:?}",
                input.len(),
                meta.input.shape
            ));
        }

        let t0 = Instant::now();
        let in_buf = self
            .client
            .buffer_from_host_buffer::<f32>(input, &meta.input.shape, None)
            .map_err(|e| anyhow!("uploading input: {e}"))?;
        let t1 = Instant::now();

        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(loaded.weights.len() + 1);
        args.extend(loaded.weights.iter());
        args.push(&in_buf);
        let result = loaded
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        let t2 = Instant::now();

        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output: {e}"))?;
        // aot.py lowers with return_tuple=True: outputs are a 1-tuple.
        let out = literal
            .to_tuple1()
            .map_err(|e| anyhow!("untupling output: {e}"))?;
        let values =
            out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
        let t3 = Instant::now();

        if values.len() != meta.output.numel() {
            return Err(anyhow!(
                "{name}: output has {} elements, manifest says {:?}",
                values.len(),
                meta.output.shape
            ));
        }

        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.upload_us += (t1 - t0).as_micros() as u64;
        s.execute_us += (t2 - t1).as_micros() as u64;
        s.download_us += (t3 - t2).as_micros() as u64;
        Ok(values)
    }

    /// Artifact names available for a model, sorted by batch.
    pub fn artifacts_for_model(
        &self,
        model: &str,
        conv_impl: &str,
    ) -> Vec<ArtifactMeta> {
        let mut v: Vec<ArtifactMeta> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.conv_impl == conv_impl)
            .cloned()
            .collect();
        v.sort_by_key(|a| a.batch);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_artifacts_dir;

    fn engine_or_skip() -> Option<Engine> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Engine::open(&dir).unwrap())
    }

    #[test]
    fn tinynet_pallas_matches_golden() {
        let Some(e) = engine_or_skip() else { return };
        let art = e.manifest().artifact("tinynet_b1_pallas").unwrap().clone();
        let (input, expect) = e.manifest().read_golden(&art).unwrap();
        let got = e.execute("tinynet_b1_pallas", &input).unwrap();
        assert_eq!(got.len(), expect.len());
        for (g, w) in got.iter().zip(&expect) {
            assert!(
                (g - w).abs() <= 1e-4 + 1e-4 * w.abs(),
                "got {g}, want {w}"
            );
        }
    }

    #[test]
    fn tinynet_pallas_and_jnp_agree() {
        let Some(e) = engine_or_skip() else { return };
        let art = e.manifest().artifact("tinynet_b1_jnp").unwrap().clone();
        let (input, _) = e.manifest().read_golden(&art).unwrap();
        let a = e.execute("tinynet_b1_pallas", &input).unwrap();
        let b = e.execute("tinynet_b1_jnp", &input).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-4 + 1e-4 * y.abs());
        }
    }

    #[test]
    fn wrong_input_size_rejected() {
        let Some(e) = engine_or_skip() else { return };
        let err = e.execute("tinynet_b1_pallas", &[0.0; 7]).unwrap_err();
        assert!(err.to_string().contains("input has 7"));
    }

    #[test]
    fn unknown_artifact_rejected() {
        let Some(e) = engine_or_skip() else { return };
        assert!(e.execute("nope_b1_jnp", &[]).is_err());
    }

    #[test]
    fn stats_accumulate_and_compile_cached() {
        let Some(e) = engine_or_skip() else { return };
        let art = e.manifest().artifact("tinynet_b1_jnp").unwrap().clone();
        let (input, _) = e.manifest().read_golden(&art).unwrap();
        e.execute("tinynet_b1_jnp", &input).unwrap();
        let c1 = e.stats().compile_us;
        e.execute("tinynet_b1_jnp", &input).unwrap();
        let s = e.stats();
        assert_eq!(s.executions, 2);
        assert_eq!(s.compile_us, c1, "second execute must not recompile");
        assert!(s.execute_us > 0);
    }

    #[test]
    fn artifacts_for_model_sorted_by_batch() {
        let Some(e) = engine_or_skip() else { return };
        let arts = e.artifacts_for_model("alexnet", "jnp");
        assert!(arts.len() >= 2);
        for w in arts.windows(2) {
            assert!(w[0].batch < w[1].batch);
        }
    }
}
