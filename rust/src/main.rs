//! `ffcnn` CLI — leader entrypoint for the FFCNN reproduction.
//!
//! Subcommands map 1:1 onto the experiments in DESIGN.md §4:
//! `table1` (T1), `fig1` (F1), `dse` (E2), `layers` (E3), `classify` /
//! `serve` (E1/E4), `pipeline` (token-level simulator), `devices`.
//!
//! Every command assembles a `plan::Plan` from its flags and works
//! through the resolved `Deployment` (simulate / sweep / serve).
//!
//! Argument parsing is hand-rolled (`Args`): the offline build
//! environment has no clap; flags are `--key value` or `--flag`.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::anyhow;

use ffcnn::config::{
    default_artifacts_dir, ServingConfig, ShardPolicy, SloPolicy,
};
use ffcnn::coordinator::{Pace, Policy};
use ffcnn::data;
use ffcnn::fpga::device::{self, DEVICES};
use ffcnn::fpga::dse::{
    best_fleet, fleet_sweep, Fidelity, FleetDemand, FleetSweepConfig,
    SweepSpace,
};
use ffcnn::fpga::timing::OverlapPolicy;
use ffcnn::models;
use ffcnn::plan::Plan;
use ffcnn::report::{render_fig1, render_table1, table1_rows_with};
use ffcnn::Result;

const USAGE: &str = "\
ffcnn — FFCNN reproduction CLI (see DESIGN.md §4)

USAGE: ffcnn <command> [--key value] [--flag]

COMMANDS:
  table1    [--model alexnet] [--overlap full|within_group|none]
            [--weight-cache 0]    KiB of on-chip weight prefetch cache
                                  for the FFCNN rows (ablation)
  fig1      [--model vgg11]                        reproduce Fig. 1
  dse       [--device stratix10] [--model alexnet] [--batch 1]
            [--fidelity analytic|pipeline|pipeline-exact]
            [--overlap-sweep]     sweep overlap on/off x channel depth
            [--precision-sweep]   also sweep fp32/fixed16/fixed8
            [--shard-sweep]       also sweep the batch shard count
                                  (boards per batch; break-even table)
            [--weight-cache-sweep] also sweep the on-chip weight
                                  prefetch cache (KiB; M20K trade)
            [--fleet-sweep]       capacity planning: the cheapest
                                  mixed-device fleet (by aggregate
                                  DSPs) holding a multi-model mix; uses
                                  --models/--mix/--qps/--p99 below
            [--models alexnet,vgg16]  served mix for --fleet-sweep
            [--mix 0.7,0.3]       request share per model (normalized;
                                  default equal)
            [--qps 100]           total rate the fleet must sustain
            [--p99 50]            per-request bound (ms); one value or
                                  one per model
            [--fleet-devices arria10,stratix10]  candidate board types
            [--max-boards 4]      largest fleet enumerated
  layers    [--model alexnet] [--device stratix10] [--batch 1]
  pipeline  [--model alexnet] [--device stratix10] [--batch 1] [--exact]
            [--overlap within_group|full|none]
  classify  [--model alexnet] [--batch 1] [--conv-impl jnp]
            [--device stratix10] [--iters 3]
  serve     [--model alexnet] [--device stratix10] [--requests 64]
            [--rate 0] [--boards 1] [--max-batch 8] [--pace-fpga]
            [--pace-immediate]    engine-less boards (no artifacts
                                  needed): measures the coordinator
            [--saturate]          closed-loop bulk saturation via
                                  submit_many — the raw-speed pass
            [--bulk 64]           requests per bulk submission group
                                  (with --saturate)
            [--seed 7]            Poisson trace seed (reproducible but
                                  variable replays)
            [--batch-size 1]      batch per request: with --rate this
                                  replays an open-loop *batched* trace
                                  (E4 shard policies under Poisson
                                  load); without it, closed-loop
                                  classify_batch calls
            [--shards 1]          split each batch over this many boards
                                  (needs --batch-size > 1)
            [--slo-p99 0]         closed-loop control: admission +
                                  adaptive knobs hold this p99 target
                                  (ms; 0 = static plan, no shedding)
            [--slo-queue 64]      admission bound (max pending
                                  requests) while the SLO loop is on
            [--models a,b]        serve several models on one fleet
                                  (closed-loop mixed workload with
                                  per-model latency and weight-swap
                                  accounting; unknown names are
                                  rejected up front)
            [--mix 0.7,0.3]       request share per model (with
                                  --models; normalized, default equal)
            [--affinity-off]      disable model-affinity routing —
                                  boards take any model and the swap
                                  counters show what that costs
  simtest   [--num-seeds 100] [--seed 0]   deterministic robustness
            [--scenario NAME]     run one scenario (default: all; see
                                  --list) on the seeded simulated
                                  scheduler — every failure prints a
                                  replayable (scenario, seed) pair
            [--workers 0]         seed fan-out threads (0 = all cores)
            [--fail-file PATH]    write failing (scenario, seed) pairs
                                  (CI artifact; empty file on success)
            [--list]              list scenario names and exit
  devices                                          list device profiles

GLOBAL: --artifacts <dir>   artifact directory (default ./artifacts)
";

/// Minimal `--key value` / `--flag` parser.
struct Args {
    kv: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut kv = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                return Err(anyhow!("unexpected argument {a:?}\n{USAGE}"));
            };
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                kv.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Args { kv, flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.kv.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} wants an integer, got {v:?}")),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.kv.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} wants a number, got {v:?}")),
        }
    }

    fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    let artifacts = args
        .kv
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);

    match cmd.as_str() {
        "table1" => cmd_table1(&args),
        "fig1" => cmd_fig1(&args),
        "dse" => cmd_dse(&args),
        "layers" => cmd_layers(&args),
        "pipeline" => cmd_pipeline(&args),
        "classify" => cmd_classify(&args, artifacts),
        "serve" => cmd_serve(&args, artifacts),
        "simtest" => cmd_simtest(&args),
        "devices" => {
            println!(
                "{:<12}{:<22}{:>8}{:>8}{:>10}{:>10}{:>10}",
                "name", "device", "kLUTs", "DSPs", "M20K Mb", "Fmax",
                "DDR GB/s"
            );
            for d in DEVICES {
                println!(
                    "{:<12}{:<22}{:>8}{:>8}{:>10.0}{:>10.0}{:>10.1}",
                    d.name, d.device, d.luts_k, d.dsps, d.m20k_mbits,
                    d.fmax_mhz, d.ddr_gbps
                );
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}\n{USAGE}")),
    }
}

/// Parse a comma-separated `--models` list, rejecting unknown names
/// before any plan is built — the error carries the full catalog.
fn parse_model_list(arg: &str) -> Result<Vec<String>> {
    let names: Vec<String> = arg
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        return Err(anyhow!(
            "--models wants a comma-separated list of model names \
             (have {:?})",
            models::model_names()
        ));
    }
    for n in &names {
        if models::by_name(n).is_none() {
            return Err(anyhow!(
                "unknown model {n:?} in --models (have {:?})",
                models::model_names()
            ));
        }
    }
    Ok(names)
}

/// Parse `--mix` into normalized per-model request shares (default:
/// equal shares).
fn parse_mix(args: &Args, n: usize) -> Result<Vec<f64>> {
    let Some(raw) = args.kv.get("mix") else {
        return Ok(vec![1.0 / n as f64; n]);
    };
    let parts: Vec<f64> = raw
        .split(',')
        .map(|s| {
            s.trim().parse::<f64>().map_err(|_| {
                anyhow!("--mix wants comma-separated numbers, got {raw:?}")
            })
        })
        .collect::<Result<_>>()?;
    if parts.len() != n {
        return Err(anyhow!(
            "--mix has {} weight(s) for {n} model(s)",
            parts.len()
        ));
    }
    let total: f64 = parts.iter().sum();
    if !(total > 0.0) || parts.iter().any(|w| *w < 0.0) {
        return Err(anyhow!("--mix weights must be non-negative and sum > 0"));
    }
    Ok(parts.iter().map(|w| w / total).collect())
}

fn overlap_arg(args: &Args, default: &str) -> Result<OverlapPolicy> {
    match args.get("overlap", default).as_str() {
        "none" => Ok(OverlapPolicy::None),
        "within_group" => Ok(OverlapPolicy::WithinGroup),
        "full" => Ok(OverlapPolicy::Full),
        other => Err(anyhow!(
            "unknown overlap policy {other:?} (none|within_group|full)"
        )),
    }
}

fn cmd_table1(args: &Args) -> Result<()> {
    let overlap = overlap_arg(args, "full")?;
    let weight_cache = args.get_usize("weight-cache", 0)?;
    let plan = Plan::builder()
        .model(&args.get("model", "alexnet"))
        .overlap(overlap)
        .weight_cache_kib(weight_cache)
        .build()?;
    let dep = plan.deploy()?;
    let m = dep.model();
    println!(
        "Table 1 — {} ({:.2} GOPs/image, {:.1}M params, FFCNN overlap \
         {overlap:?}, weight cache {weight_cache} KiB)\n",
        m.name,
        m.total_ops() as f64 / 1e9,
        m.total_params() as f64 / 1e6
    );
    println!(
        "{}",
        render_table1(&table1_rows_with(m, overlap, weight_cache))
    );
    if weight_cache > 0 && overlap == OverlapPolicy::Full {
        println!(
            "(note: under Full overlap the analytic model already \
             assumes perfect cross-group prefetch, so the weight cache \
             moves nothing here — rerun with --overlap within_group to \
             see the ablation)"
        );
    }
    println!(
        "(times from each design's cycle model; GOPS = executed ops / \
         time, computed uniformly — see EXPERIMENTS.md §T1)"
    );
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let plan = Plan::builder().model(&args.get("model", "vgg11")).build()?;
    let dep = plan.deploy()?;
    println!("{}", render_fig1(dep.model()));
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    if args.has("fleet-sweep") {
        return cmd_fleet_sweep(args);
    }
    let batch = args.get_usize("batch", 1)?;
    let fidelity = match args.get("fidelity", "analytic").as_str() {
        "analytic" => Fidelity::Analytic,
        "pipeline" => Fidelity::PipelineFast,
        "pipeline-exact" => Fidelity::PipelineExact,
        other => {
            return Err(anyhow!(
                "unknown fidelity {other:?} (analytic|pipeline|pipeline-exact)"
            ))
        }
    };
    let mut space = if args.has("precision-sweep") {
        SweepSpace::with_precision_overlap_and_depth()
    } else if args.has("overlap-sweep") {
        SweepSpace::with_overlap_and_depth()
    } else {
        SweepSpace::default()
    };
    if args.has("shard-sweep") {
        // Compose the shard axis onto whatever base space was picked
        // (`with_shards()` covers the flag-less default).
        space.shards = SweepSpace::with_shards().shards;
    }
    if args.has("weight-cache-sweep") {
        // Compose the weight-cache axis the same way; the prefetch
        // window only fires under cross-group overlap, so make sure
        // `Full` is in the grid.
        space.weight_caches = SweepSpace::with_weight_cache().weight_caches;
        if !space.overlaps.contains(&OverlapPolicy::Full) {
            space.overlaps.push(OverlapPolicy::Full);
        }
    }
    let mut plan = Plan::builder()
        .model(&args.get("model", "alexnet"))
        .device(&args.get("device", "stratix10"))
        .fidelity(fidelity)
        .sweep(space)
        .build()?;
    let dep = plan.deploy()?;
    let t0 = std::time::Instant::now();
    let sweep = dep.sweep_at(batch);
    let sweep_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "DSE: {} on {} (batch {batch}, {fidelity:?}) — {} points, \
         {} feasible, swept in {sweep_ms:.1} ms",
        plan.model,
        dep.device().device,
        sweep.points.len(),
        sweep.feasible_count()
    );
    println!(
        "{:<8}{:<8}{:<8}{:<10}{:<10}{:<8}{:<14}{:>8}{:>12}{:>10}{:>14}",
        "vec", "lane", "depth", "cache", "prec", "shards", "overlap",
        "DSPs", "time(ms)", "GOPS", "GOPS/DSP"
    );
    for p in sweep.pareto() {
        println!(
            "{:<8}{:<8}{:<8}{:<10}{:<10}{:<8}{:<14}{:>8}{:>12.2}{:>10.1}{:>14.3}",
            p.params.vec_size,
            p.params.lane_num,
            p.params.channel_depth,
            format!("{}K", p.params.weight_cache_kib),
            format!("{:?}", p.params.precision),
            p.shards,
            format!("{:?}", p.overlap),
            p.usage.dsps,
            p.time_ms,
            p.gops,
            p.gops_per_dsp
        );
    }
    if plan.sweep.weight_caches.len() > 1 {
        println!(
            "\nbest per weight cache (KiB; latency falls until the \
             next group's weight tile — or the donor groups' compute \
             slack — is exhausted, M20K cost rises throughout):"
        );
        for (kib, p) in sweep.best_latency_per_weight_cache() {
            println!(
                "  {kib:>6} KiB: vec={:<3} lane={:<3} {:?} -> {:>9.4} \
                 ms/image ({:.2} MB M20K)",
                p.params.vec_size,
                p.params.lane_num,
                p.overlap,
                p.time_ms,
                p.usage.m20k_bytes / 1e6
            );
        }
    }
    if plan.sweep.shards.len() > 1 {
        // Candidates collapse to their effective splits at this batch
        // (a swept 8 at batch 2 can only dispatch 2 shards); be
        // explicit when the whole axis degenerated rather than
        // printing a one-row "break-even" table.
        let mut eff: Vec<usize> = plan
            .sweep
            .shards
            .iter()
            .map(|&k| ffcnn::fpga::pipeline::shard_split(batch, k).1)
            .collect();
        eff.sort_unstable();
        eff.dedup();
        if eff.len() > 1 {
            println!(
                "\nbest per shard count (batch {batch}; latency falls \
                 until the per-shard dispatch+gather overhead catches \
                 the shrinking sub-batch):"
            );
            for (k, p) in sweep.best_latency_per_shards() {
                println!(
                    "  {k:>2} shard(s): vec={:<3} lane={:<3} -> {:>9.4} \
                     ms/image ({:>9.3} ms/batch)",
                    p.params.vec_size,
                    p.params.lane_num,
                    p.time_ms,
                    p.time_ms * batch as f64
                );
            }
        } else {
            println!(
                "\nshard sweep collapsed: at batch {batch} every \
                 candidate in {:?} clamps to {} shard(s) — raise \
                 --batch to explore the shard axis",
                plan.sweep.shards, eff[0]
            );
        }
    }
    if plan.sweep.precisions.len() > 1 {
        println!("\nbest per precision:");
        let density = sweep.best_density_per_precision();
        for (prec, p) in sweep.best_latency_per_precision() {
            let dens = density
                .iter()
                .find(|(q, _)| *q == prec)
                .map(|(_, d)| d.gops_per_dsp)
                .unwrap_or(0.0);
            println!(
                "  {:<10} vec={:<3} lane={:<3} -> {:>8.2} ms | best \
                 density {:.3} GOPS/DSP",
                format!("{prec:?}"),
                p.params.vec_size,
                p.params.lane_num,
                p.time_ms,
                dens
            );
        }
    }
    if let Some(b) = sweep.best_latency() {
        println!(
            "\nlatency-optimal: vec={} lane={} depth={} cache={}K {:?} \
             {:?} -> {:.2} ms",
            b.params.vec_size,
            b.params.lane_num,
            b.params.channel_depth,
            b.params.weight_cache_kib,
            b.params.precision,
            b.overlap,
            b.time_ms
        );
    }
    if let Some(b) = sweep.best_density() {
        println!(
            "density-optimal: vec={} lane={} -> {:.3} GOPS/DSP",
            b.params.vec_size, b.params.lane_num, b.gops_per_dsp
        );
    }
    // Reify the winner: the adopted plan is what a follow-up
    // `simulate`/`serve` run would consume (Plan::adopt).
    if let Some(best) = sweep.best_latency() {
        plan.adopt(best)?;
        println!(
            "plan adopted the latency optimum (design {}x{} depth {} \
             cache {}K {:?}, overlap {:?}, shard policy {:?} over {} \
             board(s))",
            plan.design.vec_size,
            plan.design.lane_num,
            plan.design.channel_depth,
            plan.design.weight_cache_kib,
            plan.design.precision,
            plan.overlap,
            plan.serving.shard,
            plan.serving.boards
        );
    }
    Ok(())
}

/// `ffcnn dse --fleet-sweep` — the capacity-planning table: enumerate
/// small fleet compositions over the candidate devices and print the
/// cheapest (by aggregate purchased DSPs) that holds every model's
/// QPS share within its p99 bound.
fn cmd_fleet_sweep(args: &Args) -> Result<()> {
    let names = parse_model_list(&args.get("models", "alexnet,vgg16"))?;
    let mix = parse_mix(args, names.len())?;
    let qps = args.get_f64("qps", 100.0)?;
    let p99: Vec<f64> = {
        let raw = args.get("p99", "50");
        let parts: Vec<f64> = raw
            .split(',')
            .map(|s| {
                s.trim().parse::<f64>().map_err(|_| {
                    anyhow!("--p99 wants ms value(s), got {raw:?}")
                })
            })
            .collect::<Result<_>>()?;
        match parts.len() {
            1 => vec![parts[0]; names.len()],
            n if n == names.len() => parts,
            n => {
                return Err(anyhow!(
                    "--p99 has {n} bound(s) for {} model(s)",
                    names.len()
                ))
            }
        }
    };
    let devices: Vec<&'static device::DeviceProfile> = args
        .get("fleet-devices", "arria10,stratix10")
        .split(',')
        .map(|s| {
            let s = s.trim();
            device::by_name(s).ok_or_else(|| {
                anyhow!("unknown device {s:?} in --fleet-devices")
            })
        })
        .collect::<Result<_>>()?;
    let cfg = FleetSweepConfig {
        max_boards: args.get_usize("max-boards", 4)?,
        max_batch: args.get_usize("max-batch", 16)?,
        ..Default::default()
    };
    let demands: Vec<FleetDemand> = names
        .iter()
        .zip(&mix)
        .zip(&p99)
        .map(|((name, &share), &p99_ms)| FleetDemand {
            model: models::by_name(name).expect("validated by parse_model_list"),
            qps: share * qps,
            p99_ms,
        })
        .collect();
    println!(
        "fleet sweep: {qps:.0} req/s over {} model(s), up to {} board(s) \
         from {:?}",
        names.len(),
        cfg.max_boards,
        devices.iter().map(|d| d.name).collect::<Vec<_>>()
    );
    for (d, name) in demands.iter().zip(&names) {
        println!(
            "  {name:<10} {:>8.1} req/s, p99 <= {:.1} ms",
            d.qps, d.p99_ms
        );
    }
    let options = fleet_sweep(&demands, &devices, &cfg);
    if options.is_empty() {
        return Err(anyhow!(
            "no candidate device can place the mix's heaviest model"
        ));
    }
    println!(
        "\n{:<36}{:>8}{:>10}{:>8}  {}",
        "fleet", "boards", "DSPs", "holds?", "served req/s per model"
    );
    for o in options.iter().take(8) {
        let members = o
            .members
            .iter()
            .map(|m| {
                format!(
                    "{}x {} ({}x{})",
                    m.count, m.device, m.params.vec_size, m.params.lane_num
                )
            })
            .collect::<Vec<_>>()
            .join(" + ");
        let served = o
            .served
            .iter()
            .map(|s| format!("{s:.1}"))
            .collect::<Vec<_>>()
            .join(" / ");
        println!(
            "{:<36}{:>8}{:>10}{:>8}  {}",
            members,
            o.total_boards,
            o.total_dsps,
            if o.feasible { "yes" } else { "no" },
            served
        );
    }
    match best_fleet(&options) {
        Some(best) => {
            let members = best
                .members
                .iter()
                .map(|m| format!("{}x {}", m.count, m.device))
                .collect::<Vec<_>>()
                .join(" + ");
            let headroom = demands
                .iter()
                .enumerate()
                .map(|(m, d)| best.served[m] / d.qps.max(f64::MIN_POSITIVE))
                .fold(f64::INFINITY, f64::min);
            println!(
                "\ncheapest fleet holding the mix: {members} ({} DSPs \
                 aggregate); slimmest model has {headroom:.2}x its \
                 required rate",
                best.total_dsps
            );
        }
        None => println!(
            "\nno enumerated fleet holds the mix — raise --max-boards, \
             relax --p99, or widen --fleet-devices"
        ),
    }
    Ok(())
}

fn cmd_layers(args: &Args) -> Result<()> {
    let batch = args.get_usize("batch", 1)?;
    let plan = Plan::builder()
        .model(&args.get("model", "alexnet"))
        .device(&args.get("device", "stratix10"))
        .build()?;
    let dep = plan.deploy()?;
    let (m, d, p) = (dep.model(), dep.device(), &plan.design);
    let usage = dep.resources();
    let t = dep.analytic(batch);
    println!(
        "{} on {} (vec={} lane={}, {} DSPs, batch {batch}): {:.2} ms, \
         {:.1} GOPS, DDR {:.1} MB (unfused {:.1} MB, saving {:.0}%)\n",
        m.name,
        d.device,
        p.vec_size,
        p.lane_num,
        usage.dsps,
        t.time_per_image_ms(),
        t.gops(),
        t.dram_bytes as f64 / 1e6,
        t.dram_bytes_unfused as f64 / 1e6,
        t.fusion_traffic_saving() * 100.0
    );
    println!(
        "{:<34}{:>12}{:>12}{:>12}{:>10}",
        "fused group", "compute(cy)", "mem(cy)", "cycles", "bound"
    );
    for g in &t.groups {
        println!(
            "{:<34}{:>12}{:>12}{:>12}{:>10}",
            g.layers.join("+"),
            g.compute_cycles,
            g.mem_cycles,
            g.cycles,
            format!("{:?}", g.bound)
        );
    }
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let batch = args.get_usize("batch", 1)?;
    let overlap = overlap_arg(args, "within_group")?;
    let plan = Plan::builder()
        .model(&args.get("model", "alexnet"))
        .device(&args.get("device", "stratix10"))
        .overlap(overlap)
        .fidelity(if args.has("exact") {
            Fidelity::PipelineExact
        } else {
            Fidelity::PipelineFast
        })
        .build()?;
    let dep = plan.deploy()?;
    let tok = dep.simulate(batch);
    let ana = dep.analytic(batch);
    println!(
        "token-level ({overlap:?}): {:.2} ms | analytic: {:.2} ms | \
         ratio {:.3}",
        tok.time_ms(),
        ana.time_ms(),
        tok.total_cycles as f64 / ana.total_cycles as f64
    );
    println!(
        "\n{:<34}{:>10}{:>12}{:>6}{:>30}",
        "group", "tokens", "cycles", "path", "backpressure rd/cv/fu/wr"
    );
    for g in &tok.groups {
        println!(
            "{:<34}{:>10}{:>12}{:>6}{:>30}",
            g.layers.join("+"),
            g.tokens,
            g.cycles,
            if g.exact { "exact" } else { "fast" },
            format!("{:?}", g.backpressure_cycles)
        );
    }
    Ok(())
}

fn cmd_classify(args: &Args, artifacts: PathBuf) -> Result<()> {
    use ffcnn::runtime::Engine;
    let batch = args.get_usize("batch", 1)?;
    let iters = args.get_usize("iters", 3)?;
    let plan = Plan::builder()
        .model(&args.get("model", "alexnet"))
        .device(&args.get("device", "stratix10"))
        .conv_impl(&args.get("conv-impl", "jnp"))
        .artifacts_dir(artifacts)
        .build()?;
    let dep = plan.deploy()?;
    let engine = Engine::open(&plan.artifacts_dir)?;
    let artifact = plan.artifact_name(batch);
    let input = data::synth_images(batch, dep.model().in_shape, 42);
    println!("compiling {artifact} ...");
    engine.warm(&artifact)?;
    for i in 0..iters {
        let t0 = std::time::Instant::now();
        let logits = engine.execute(&artifact, &input)?;
        let host_ms = t0.elapsed().as_secs_f64() * 1e3;
        let sim = dep.analytic(batch);
        let classes = logits.len() / batch;
        let preds: Vec<usize> = (0..batch)
            .map(|b| {
                ffcnn::coordinator::argmax(
                    &logits[b * classes..(b + 1) * classes],
                )
            })
            .collect();
        println!(
            "iter {i}: host(pjrt) {:.1} ms | simulated {} {:.2} ms \
             ({:.1} GOPS) | preds {:?}",
            host_ms,
            dep.device().name,
            sim.time_ms(),
            sim.gops(),
            preds
        );
    }
    let s = engine.stats();
    println!(
        "engine stats: {} execs, compile {:.1} ms, upload {:.1} ms, \
         execute {:.1} ms, download {:.1} ms",
        s.executions,
        s.compile_us as f64 / 1e3,
        s.upload_us as f64 / 1e3,
        s.execute_us as f64 / 1e3,
        s.download_us as f64 / 1e3
    );
    Ok(())
}

fn cmd_serve(args: &Args, artifacts: PathBuf) -> Result<()> {
    let requests = args.get_usize("requests", 64)?;
    let rate = args.get_f64("rate", 0.0)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let shards = args.get_usize("shards", 1)?;
    let batch_size = args.get_usize("batch-size", 1)?;
    if shards > 1 && batch_size <= 1 {
        // Sharding splits *batches*; the single-image trace path never
        // builds one, so the flag would be silently inert.
        return Err(anyhow!(
            "--shards {shards} only applies to whole-batch serving: \
             add --batch-size <B> (e.g. --batch-size 64)"
        ));
    }
    let slo_p99 = args.get_usize("slo-p99", 0)? as u64;
    let serving = ServingConfig {
        boards: args.get_usize("boards", 1)?,
        max_batch: args.get_usize("max-batch", 8)?,
        shard: if shards > 1 {
            ShardPolicy::SplitOver(shards)
        } else {
            ShardPolicy::None
        },
        slo: (slo_p99 > 0).then_some(SloPolicy::target_ms(
            slo_p99,
            args.get_usize("slo-queue", 64)?,
        )),
        ..Default::default()
    };
    // Multi-model serving: `--models` names are validated here, at
    // parse time, before any plan or service is built.
    let fleet_models = match args.kv.get("models") {
        Some(raw) => parse_model_list(raw)?,
        None => Vec::new(),
    };
    // With --models (and no explicit --model) the first served model
    // is the plan's primary.
    let primary = if args.kv.contains_key("model") {
        args.get("model", "alexnet")
    } else if let Some(first) = fleet_models.first() {
        first.clone()
    } else {
        "alexnet".to_string()
    };
    let mut builder = Plan::builder()
        .model(&primary)
        .device(&args.get("device", "stratix10"))
        .artifacts_dir(artifacts)
        .serving(serving)
        .pace(if args.has("pace-immediate") {
            Pace::Immediate
        } else if args.has("pace-fpga") {
            Pace::Fpga
        } else {
            Pace::None
        })
        .policy(Policy::LeastOutstanding);
    for name in &fleet_models {
        builder = builder.serve_model(name);
    }
    if args.has("affinity-off") {
        builder = builder.affinity(false);
    }
    let plan = builder.build()?;
    let dep = plan.deploy()?;
    let in_shape = dep.model().in_shape;

    let svc = dep.serve()?;
    if fleet_models.len() > 1 {
        // Closed-loop mixed workload: requests split over the served
        // models by --mix (deterministic error-diffusion proportioning,
        // so shares are exact), with per-model latency and the fleet's
        // weight-swap bill at the end.
        use ffcnn::coordinator::LatencyHistogram;
        let mix = parse_mix(args, fleet_models.len())?;
        let shapes: Vec<(usize, usize, usize)> = fleet_models
            .iter()
            .map(|n| models::by_name(n).expect("validated").in_shape)
            .collect();
        let hists: Vec<LatencyHistogram> =
            fleet_models.iter().map(|_| LatencyHistogram::new()).collect();
        let mut counts = vec![0u64; fleet_models.len()];
        let mut acc = vec![0.0f64; fleet_models.len()];
        for r in 0..requests {
            for (a, w) in acc.iter_mut().zip(&mix) {
                *a += *w;
            }
            let m = acc
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            acc[m] -= 1.0;
            let image = data::synth_images(1, shapes[m], 1000 + r as u64);
            let reply = svc.classify_model(m, image)?;
            hists[m].record_ms(reply.latency_ms);
            counts[m] += 1;
        }
        println!(
            "served {requests} mixed requests over {} model(s) \
             ({} board(s), affinity {})",
            fleet_models.len(),
            plan.serving.boards,
            if plan.affinity() { "on" } else { "off" }
        );
        for (m, name) in fleet_models.iter().enumerate() {
            println!(
                "  {name:<10} {:>6} req ({:>5.1}%) | latency: {}",
                counts[m],
                counts[m] as f64 / requests.max(1) as f64 * 100.0,
                hists[m].summary()
            );
        }
        if let Some(fleet) = svc.fleet() {
            println!(
                "weight swaps: {} total, {:.3} ms stalled",
                fleet.total_swaps(),
                fleet.total_swap_nanos() as f64 / 1e6
            );
            for b in 0..fleet.boards() {
                let resident = fleet
                    .resident(b)
                    .and_then(|m| fleet_models.get(m))
                    .map(|s| s.as_str())
                    .unwrap_or("-");
                println!(
                    "  board[{b}]: resident {resident}, {} swap(s)",
                    fleet.swaps_of(b)
                );
            }
        }
        return Ok(());
    }
    if args.has("saturate") {
        // Closed-loop saturation: hammer submit_many as fast as
        // replies resolve.  One shared image (zero-copy), bulk groups
        // of --bulk requests — measures the coordinator's raw
        // submit→route→batch→gather speed, which is the whole story
        // under --pace-immediate.
        use ffcnn::coordinator::LatencyHistogram;
        let bulk = args.get_usize("bulk", 64)?.max(1);
        let image: std::sync::Arc<[f32]> =
            data::synth_images(1, in_shape, 1000).into();
        let hist = LatencyHistogram::new();
        let mut served = 0u64;
        let mut errors = 0u64;
        let t0 = std::time::Instant::now();
        while ((served + errors) as usize) < requests {
            let n = bulk.min(requests - (served + errors) as usize);
            let set = svc.submit_many(
                std::iter::repeat_with(|| image.clone()).take(n),
            )?;
            set.wait_each(|r| match r {
                Ok(reply) => {
                    hist.record_ms(reply.latency_ms);
                    served += 1;
                }
                Err(_) => errors += 1,
            });
        }
        let wall_s = t0.elapsed().as_secs_f64();
        println!(
            "saturate: {served} ok / {errors} err in {wall_s:.3}s -> \
             {:.0} req/s (pace {:?}, {} board(s), bulk {bulk})",
            served as f64 / wall_s,
            plan.pace,
            plan.serving.boards
        );
        println!("latency: {}", hist.summary());
        return Ok(());
    }
    if batch_size > 1 && rate <= 0.0 {
        // Closed-loop whole-batch serving: each request is one flat
        // batch, split across boards per the shard policy and
        // gathered in order.
        use ffcnn::coordinator::LatencyHistogram;
        let hist = LatencyHistogram::new();
        for r in 0..requests {
            let flat =
                data::synth_images(batch_size, in_shape, 1000 + r as u64);
            let reply = svc.classify_batch(flat)?;
            hist.record_ms(reply.latency_ms);
        }
        println!(
            "served {requests} batches of {batch_size} (shard policy: \
             {:?} over {} board(s))",
            plan.serving.shard, plan.serving.boards
        );
        println!("batch latency: {}", hist.summary());
        return Ok(());
    }
    // Open-loop trace replay.  With --batch-size > 1 the trace entries
    // are whole-batch arrivals (Poisson-batched), which travel through
    // `submit_batch` under the plan's shard policy — the E4 setup for
    // comparing `ShardPolicy` under Poisson load.
    let trace = if rate > 0.0 && batch_size > 1 {
        data::poisson_batch_trace(requests, rate, batch_size, seed)
    } else if rate > 0.0 {
        data::poisson_trace(requests, rate, seed)
    } else {
        data::burst_trace(requests)
    };
    let report = svc.run_trace(
        &trace,
        |t| data::synth_images(t.batch, in_shape, 1000 + t.id),
        1.0,
    );
    println!("{report}");
    if let Some(plane) = svc.control() {
        // With --slo-p99 on, trace "errors" are mostly typed sheds:
        // show the closed loop's side of the story.
        println!(
            "control: {} admitted, {} shed ({:.1}% of arrivals), \
             {} event(s) logged",
            plane.admitted_total(),
            plane.shed_total(),
            plane.shed_fraction() * 100.0,
            plane.events().len()
        );
        for line in plane.event_log() {
            println!("  {line}");
        }
    } else if report.errors > 0 && rate > 0.0 {
        // Replayability on failure: the trace is fully determined by
        // its seed, so print the exact flags that rebuild it.
        println!(
            "(trace had {} error(s); replay it with --rate {rate} \
             --requests {requests} --batch-size {batch_size} --seed {seed})",
            report.errors
        );
    }
    Ok(())
}

fn cmd_simtest(args: &Args) -> Result<()> {
    use ffcnn::coordinator::{run_seeds, scenario_names};
    if args.has("list") {
        for n in scenario_names() {
            println!("{n}");
        }
        return Ok(());
    }
    let num_seeds = args.get_usize("num-seeds", 100)? as u64;
    let seed_start = args.get_usize("seed", 0)? as u64;
    let scenario = args.kv.get("scenario").cloned();
    let workers = match args.get_usize("workers", 0)? {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        n => n,
    };
    let t0 = std::time::Instant::now();
    let report = run_seeds(scenario.as_deref(), seed_start, num_seeds, workers)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let what = match &scenario {
        Some(s) => s.clone(),
        None => format!("{} scenarios", scenario_names().len()),
    };
    println!(
        "simtest: {} run(s) ({num_seeds} seed(s) from {seed_start} x \
         {what}) in {wall_s:.2}s — {} failed",
        report.runs,
        report.failures.len()
    );
    if let Some(path) = args.kv.get("fail-file") {
        // Always written (empty on success) so CI can upload it
        // unconditionally as the failing-seed artifact.
        let mut out = String::new();
        for f in &report.failures {
            out.push_str(&format!("{} {}\n", f.scenario, f.seed));
        }
        std::fs::write(path, out)?;
    }
    if !report.passed() {
        println!("failing seeds (replay: simtest --scenario NAME --seed SEED --num-seeds 1):");
        for f in &report.failures {
            println!("  {} {}", f.scenario, f.seed);
        }
        return Err(anyhow!("simtest: {} failing run(s)", report.failures.len()));
    }
    Ok(())
}
