//! Run configuration: device + design point + serving parameters.
//!
//! This is the *legacy* configuration surface: the canonical artifact
//! is now [`crate::plan::Plan`] (which reifies the same fields plus
//! precision, fidelity, routing policy, pace and the sweep space, and
//! round-trips losslessly through JSON).  `RunConfig` remains as the
//! input of the deprecated `InferenceService::start` shim and lifts
//! into a plan via `Plan::from_run_config`.  Parsing is strict:
//! unknown JSON keys are an error naming them, so stale configs fail
//! loudly instead of silently running with defaults.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context};

use crate::fpga::device::{self, DeviceProfile};
use crate::fpga::timing::{DesignParams, OverlapPolicy};
use crate::plan::{
    design_from_json, design_to_json, overlap_from_str, overlap_to_str,
    serving_from_json, serving_to_json,
};
use crate::util::Json;
use crate::Result;

/// How `InferenceService` places one incoming multi-image batch
/// across boards (`submit_batch` / `classify_batch`).
///
/// The router balances *requests*; without sharding a large batch
/// parks on a single board while its peers idle.  `SplitOver(k)`
/// splits a batch of `B` images into up to `k` contiguous shards of
/// `ceil(B / k)` images, dispatches each shard to a distinct
/// least-loaded board through the normal routing/work-stealing
/// machinery, and gathers the per-shard logits back into one reply in
/// submission order.  Sharding wins when the batch is large and
/// boards are idle; it loses at small batches, where the per-shard
/// dispatch + gather overhead outweighs the saved board time (the
/// shard-aware simulator mode and the `shards` sweep dimension model
/// exactly this break-even).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Serve each incoming batch whole, on one board.
    None,
    /// Split a batch over up to this many boards (clamped to the
    /// provisioned board count and the batch size at dispatch).
    SplitOver(usize),
}

impl ShardPolicy {
    /// Upper bound on shards per batch (1 = no splitting).
    pub fn max_shards(self) -> usize {
        match self {
            ShardPolicy::None => 1,
            ShardPolicy::SplitOver(k) => k.max(1),
        }
    }
}

/// How the admission controller sheds load once the SLO intake bound
/// is hit (`coordinator::control`).
///
/// Either way the rejection is a typed
/// [`ServeError::Overloaded`](crate::coordinator::ServeError) carrying
/// a `retry_after_ms` hint — overload degrades to bounded memory and
/// fast sheds, never an unbounded queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Reject arrivals only once the intake queue bound
    /// (`SloPolicy::max_queue`) is full.
    RejectNewest,
    /// Additionally rate-limit admission with a token bucket refilled
    /// at this many requests per second (burst = one bucket).
    RateLimit(u64),
}

/// The serving SLO the closed-loop controller
/// (`coordinator::control`) holds: a p99 latency target, a bound on
/// the intake queue, and the shed policy applied past that bound.
/// Attached to [`ServingConfig::slo`]; `None` serves open-loop with
/// the static plan knobs (the pre-control behavior, bit-identical).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SloPolicy {
    /// Hold measured p99 at or below this many milliseconds.
    pub p99_target_ms: u64,
    /// Admission bound: total queued requests across all boards above
    /// which new arrivals are shed (the controller may tighten this
    /// online, never past the configured value).
    pub max_queue: usize,
    /// What happens to arrivals past the bound.
    pub shed_policy: ShedPolicy,
    /// Opt in to measured host-latency feedback: on non-FPGA-paced
    /// boards the batcher feeds each executed batch's host latency
    /// into a per-item EWMA that replaces the `retry_after_ms`
    /// fallback constant (`ControlPlane::observe_host_ms`).  Off by
    /// default — the hint then derives from the cost oracle alone,
    /// the pre-opt-in behavior.
    pub host_feedback: bool,
}

impl SloPolicy {
    /// An SLO with the given p99 target, a queue bound of `max_queue`,
    /// shedding by rejection only, host feedback off.
    pub fn target_ms(p99_target_ms: u64, max_queue: usize) -> Self {
        SloPolicy {
            p99_target_ms,
            max_queue,
            shed_policy: ShedPolicy::RejectNewest,
            host_feedback: false,
        }
    }

    /// This policy with measured host-latency feedback opted in.
    pub fn with_host_feedback(mut self) -> Self {
        self.host_feedback = true;
        self
    }
}

/// Serving-side knobs for the coordinator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServingConfig {
    /// Maximum dynamic batch size (bounded by available AOT artifacts).
    pub max_batch: usize,
    /// Batching window: flush a partial batch after this many ms.
    pub max_wait_ms: u64,
    /// Number of simulated boards behind the router.
    pub boards: usize,
    /// Bounded request queue depth (admission control).
    pub queue_depth: usize,
    /// Multi-board placement of one incoming batch.
    pub shard: ShardPolicy,
    /// Closed-loop SLO policy (`None` = static open-loop serving).
    pub slo: Option<SloPolicy>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            max_batch: 4,
            max_wait_ms: 2,
            boards: 1,
            queue_depth: 256,
            shard: ShardPolicy::None,
            slo: None,
        }
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Model name (must exist in `models::by_name` and the manifest).
    pub model: String,
    /// Device short name (`arria10`, `stratix10`, ...).
    pub device: String,
    /// Conv engine design point; `None` = the FFCNN point for the device.
    pub design: Option<DesignParams>,
    /// DDR/compute overlap policy.
    pub overlap: OverlapPolicy,
    /// Artifact directory produced by `make artifacts`.
    pub artifacts_dir: PathBuf,
    /// Conv implementation of the artifact to execute (`jnp`/`pallas`).
    pub conv_impl: String,
    pub serving: ServingConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "alexnet".to_string(),
            device: "stratix10".to_string(),
            design: None,
            overlap: OverlapPolicy::WithinGroup,
            artifacts_dir: default_artifacts_dir(),
            conv_impl: "jnp".to_string(),
            serving: ServingConfig::default(),
        }
    }
}

/// `artifacts/` next to the manifest the Makefile produces; falls back
/// to the crate root so tests work from any cwd.
pub fn default_artifacts_dir() -> PathBuf {
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    for c in &candidates {
        if c.join("manifest.json").exists() {
            return c.clone();
        }
    }
    candidates[0].clone()
}

impl RunConfig {
    pub fn to_json(&self) -> Json {
        let design = match &self.design {
            None => Json::Null,
            Some(d) => design_to_json(d),
        };
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("device", Json::str(&self.device)),
            ("design", design),
            ("overlap", Json::str(overlap_to_str(self.overlap))),
            (
                "artifacts_dir",
                Json::str(&self.artifacts_dir.to_string_lossy()),
            ),
            ("conv_impl", Json::str(&self.conv_impl)),
            ("serving", serving_to_json(&self.serving)),
        ])
    }

    /// Parse a config.  Missing keys fall back to the defaults;
    /// unknown keys (top-level or nested) are an error naming them.
    pub fn from_json(v: &Json) -> Result<Self> {
        v.expect_keys(
            &[
                "model",
                "device",
                "design",
                "overlap",
                "artifacts_dir",
                "conv_impl",
                "serving",
            ],
            "run config",
        )?;
        let mut cfg = RunConfig::default();
        if let Some(m) = v.opt("model") {
            cfg.model = m.as_str()?.to_string();
        }
        if let Some(d) = v.opt("device") {
            cfg.device = d.as_str()?.to_string();
        }
        if let Some(d) = v.opt("design") {
            cfg.design = Some(design_from_json(d)?);
        }
        if let Some(o) = v.opt("overlap") {
            cfg.overlap = overlap_from_str(o.as_str()?)?;
        }
        if let Some(a) = v.opt("artifacts_dir") {
            cfg.artifacts_dir = PathBuf::from(a.as_str()?);
        }
        if let Some(c) = v.opt("conv_impl") {
            cfg.conv_impl = c.as_str()?.to_string();
        }
        if let Some(s) = v.opt("serving") {
            cfg.serving = serving_from_json(s)?;
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Resolve the device profile.
    pub fn device_profile(&self) -> Result<&'static DeviceProfile> {
        device::by_name(&self.device)
            .ok_or_else(|| anyhow!("unknown device {:?}", self.device))
    }

    /// Resolve the design point (explicit or the per-device default,
    /// shared with the plan facade).
    pub fn design_params(&self) -> Result<DesignParams> {
        Ok(self
            .design
            .unwrap_or_else(|| crate::plan::default_design_for(&self.device)))
    }

    /// Artifact name for this model at a batch size.
    pub fn artifact_name(&self, batch: usize) -> String {
        crate::plan::artifact_file_name(&self.model, batch, &self.conv_impl)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_json() {
        let mut c = RunConfig::default();
        c.design = Some(DesignParams::new(8, 4));
        c.overlap = OverlapPolicy::Full;
        let j = c.to_json().to_string();
        let d = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(d.model, c.model);
        assert_eq!(d.serving.max_batch, c.serving.max_batch);
        assert_eq!(d.design.unwrap().vec_size, 8);
        assert!(matches!(d.overlap, OverlapPolicy::Full));
    }

    #[test]
    fn device_profile_resolution() {
        let mut c = RunConfig::default();
        assert_eq!(c.device_profile().unwrap().name, "stratix10");
        c.device = "arria10".into();
        assert_eq!(c.device_profile().unwrap().fmax_mhz, 167.0);
        c.device = "nope".into();
        assert!(c.device_profile().is_err());
    }

    #[test]
    fn design_defaults_per_device() {
        let mut c = RunConfig::default();
        assert_eq!(c.design_params().unwrap().vec_size, 16);
        c.device = "arria10".into();
        assert_eq!(c.design_params().unwrap().vec_size, 32);
        c.design = Some(DesignParams::new(8, 4));
        assert_eq!(c.design_params().unwrap().lane_num, 4);
    }

    #[test]
    fn artifact_naming() {
        let c = RunConfig::default();
        assert_eq!(c.artifact_name(4), "alexnet_b4_jnp");
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ffcnn_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        let mut c = RunConfig::default();
        c.model = "resnet50".into();
        c.save(&p).unwrap();
        let d = RunConfig::load(&p).unwrap();
        assert_eq!(d.model, "resnet50");
    }

    #[test]
    fn bad_overlap_rejected() {
        let j = Json::parse(r#"{"overlap":"sometimes"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn unknown_keys_rejected_by_name() {
        // Top level: a stale/misspelled key must fail loudly.
        let j = Json::parse(r#"{"model":"alexnet","overlpa":"full"}"#)
            .unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("overlpa"), "{err}");
        // Nested design and serving blocks are checked too.
        let j = Json::parse(
            r#"{"design":{"vec_size":8,"lane_num":4,"vec":16}}"#,
        )
        .unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("\"vec\""), "{err}");
        let j =
            Json::parse(r#"{"serving":{"max_batch":2,"queue":9}}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("queue"), "{err}");
    }

    #[test]
    fn shard_policy_roundtrips_in_serving() {
        let mut c = RunConfig::default();
        c.serving.boards = 4;
        c.serving.shard = ShardPolicy::SplitOver(4);
        let j = c.to_json().to_string();
        let d = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(d.serving.shard, ShardPolicy::SplitOver(4));
        assert_eq!(ShardPolicy::None.max_shards(), 1);
        assert_eq!(ShardPolicy::SplitOver(0).max_shards(), 1);
        assert_eq!(ShardPolicy::SplitOver(3).max_shards(), 3);
    }

    #[test]
    fn slo_policy_roundtrips_in_serving() {
        // Off by default — the serialized default names no SLO and
        // parses back to None.
        let c = RunConfig::default();
        let j = c.to_json().to_string();
        let d = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(d.serving.slo, None);

        let mut c = RunConfig::default();
        c.serving.slo = Some(SloPolicy {
            p99_target_ms: 25,
            max_queue: 8,
            shed_policy: ShedPolicy::RateLimit(500),
            host_feedback: true,
        });
        let j = c.to_json().to_string();
        let d = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(d.serving.slo, c.serving.slo);

        c.serving.slo = Some(SloPolicy::target_ms(10, 4));
        let j = c.to_json().to_string();
        let d = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(
            d.serving.slo.unwrap().shed_policy,
            ShedPolicy::RejectNewest
        );

        // Unknown nested slo keys fail by name, like every block.
        let j = Json::parse(
            r#"{"serving":{"slo":{"p99_target_ms":10,"p99":5}}}"#,
        )
        .unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("\"p99\""), "{err}");
    }

    #[test]
    fn precision_roundtrips_in_design() {
        use crate::fpga::timing::Precision;
        let mut c = RunConfig::default();
        c.design = Some(
            DesignParams::new(8, 4).with_precision(Precision::Fixed16),
        );
        let j = c.to_json().to_string();
        let d = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(d.design.unwrap().precision, Precision::Fixed16);
    }
}
