//! Run configuration: device + design point + serving parameters.
//!
//! Loadable from JSON (`--config run.json`, via the in-tree parser) or
//! assembled from CLI flags; every example and bench builds one of
//! these.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context};

use crate::fpga::device::{self, DeviceProfile};
use crate::fpga::timing::{
    ffcnn_arria10_params, ffcnn_stratix10_params, DesignParams,
    OverlapPolicy,
};
use crate::util::Json;
use crate::Result;

/// Serving-side knobs for the coordinator.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Maximum dynamic batch size (bounded by available AOT artifacts).
    pub max_batch: usize,
    /// Batching window: flush a partial batch after this many ms.
    pub max_wait_ms: u64,
    /// Number of simulated boards behind the router.
    pub boards: usize,
    /// Bounded request queue depth (admission control).
    pub queue_depth: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            max_batch: 4,
            max_wait_ms: 2,
            boards: 1,
            queue_depth: 256,
        }
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Model name (must exist in `models::by_name` and the manifest).
    pub model: String,
    /// Device short name (`arria10`, `stratix10`, ...).
    pub device: String,
    /// Conv engine design point; `None` = the FFCNN point for the device.
    pub design: Option<DesignParams>,
    /// DDR/compute overlap policy.
    pub overlap: OverlapPolicy,
    /// Artifact directory produced by `make artifacts`.
    pub artifacts_dir: PathBuf,
    /// Conv implementation of the artifact to execute (`jnp`/`pallas`).
    pub conv_impl: String,
    pub serving: ServingConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "alexnet".to_string(),
            device: "stratix10".to_string(),
            design: None,
            overlap: OverlapPolicy::WithinGroup,
            artifacts_dir: default_artifacts_dir(),
            conv_impl: "jnp".to_string(),
            serving: ServingConfig::default(),
        }
    }
}

/// `artifacts/` next to the manifest the Makefile produces; falls back
/// to the crate root so tests work from any cwd.
pub fn default_artifacts_dir() -> PathBuf {
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    for c in &candidates {
        if c.join("manifest.json").exists() {
            return c.clone();
        }
    }
    candidates[0].clone()
}

fn overlap_to_str(o: OverlapPolicy) -> &'static str {
    match o {
        OverlapPolicy::None => "none",
        OverlapPolicy::WithinGroup => "within_group",
        OverlapPolicy::Full => "full",
    }
}

fn overlap_from_str(s: &str) -> Result<OverlapPolicy> {
    Ok(match s {
        "none" => OverlapPolicy::None,
        "within_group" => OverlapPolicy::WithinGroup,
        "full" => OverlapPolicy::Full,
        _ => return Err(anyhow!("unknown overlap policy {s:?}")),
    })
}

impl RunConfig {
    pub fn to_json(&self) -> Json {
        let design = match self.design {
            None => Json::Null,
            Some(d) => Json::obj(vec![
                ("vec_size", Json::num(d.vec_size as f64)),
                ("lane_num", Json::num(d.lane_num as f64)),
                ("channel_depth", Json::num(d.channel_depth as f64)),
                ("host_us_per_group", Json::num(d.host_us_per_group)),
            ]),
        };
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("device", Json::str(&self.device)),
            ("design", design),
            ("overlap", Json::str(overlap_to_str(self.overlap))),
            (
                "artifacts_dir",
                Json::str(&self.artifacts_dir.to_string_lossy()),
            ),
            ("conv_impl", Json::str(&self.conv_impl)),
            (
                "serving",
                Json::obj(vec![
                    (
                        "max_batch",
                        Json::num(self.serving.max_batch as f64),
                    ),
                    (
                        "max_wait_ms",
                        Json::num(self.serving.max_wait_ms as f64),
                    ),
                    ("boards", Json::num(self.serving.boards as f64)),
                    (
                        "queue_depth",
                        Json::num(self.serving.queue_depth as f64),
                    ),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let mut cfg = RunConfig::default();
        if let Some(m) = v.opt("model") {
            cfg.model = m.as_str()?.to_string();
        }
        if let Some(d) = v.opt("device") {
            cfg.device = d.as_str()?.to_string();
        }
        if let Some(d) = v.opt("design") {
            let mut p = DesignParams::new(
                d.get("vec_size")?.as_usize()?,
                d.get("lane_num")?.as_usize()?,
            );
            if let Some(c) = d.opt("channel_depth") {
                p.channel_depth = c.as_usize()?;
            }
            if let Some(h) = d.opt("host_us_per_group") {
                p.host_us_per_group = h.as_f64()?;
            }
            cfg.design = Some(p);
        }
        if let Some(o) = v.opt("overlap") {
            cfg.overlap = overlap_from_str(o.as_str()?)?;
        }
        if let Some(a) = v.opt("artifacts_dir") {
            cfg.artifacts_dir = PathBuf::from(a.as_str()?);
        }
        if let Some(c) = v.opt("conv_impl") {
            cfg.conv_impl = c.as_str()?.to_string();
        }
        if let Some(s) = v.opt("serving") {
            if let Some(x) = s.opt("max_batch") {
                cfg.serving.max_batch = x.as_usize()?;
            }
            if let Some(x) = s.opt("max_wait_ms") {
                cfg.serving.max_wait_ms = x.as_u64()?;
            }
            if let Some(x) = s.opt("boards") {
                cfg.serving.boards = x.as_usize()?;
            }
            if let Some(x) = s.opt("queue_depth") {
                cfg.serving.queue_depth = x.as_usize()?;
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Resolve the device profile.
    pub fn device_profile(&self) -> Result<&'static DeviceProfile> {
        device::by_name(&self.device)
            .ok_or_else(|| anyhow!("unknown device {:?}", self.device))
    }

    /// Resolve the design point (explicit or the per-device default).
    pub fn design_params(&self) -> Result<DesignParams> {
        if let Some(d) = self.design {
            return Ok(d);
        }
        Ok(match self.device.as_str() {
            "arria10" => ffcnn_arria10_params(),
            "stratix10" => ffcnn_stratix10_params(),
            // Generic default for other fabrics.
            _ => DesignParams::new(16, 8),
        })
    }

    /// Artifact name for this model at a batch size.
    pub fn artifact_name(&self, batch: usize) -> String {
        format!("{}_b{}_{}", self.model, batch, self.conv_impl)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_json() {
        let mut c = RunConfig::default();
        c.design = Some(DesignParams::new(8, 4));
        c.overlap = OverlapPolicy::Full;
        let j = c.to_json().to_string();
        let d = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(d.model, c.model);
        assert_eq!(d.serving.max_batch, c.serving.max_batch);
        assert_eq!(d.design.unwrap().vec_size, 8);
        assert!(matches!(d.overlap, OverlapPolicy::Full));
    }

    #[test]
    fn device_profile_resolution() {
        let mut c = RunConfig::default();
        assert_eq!(c.device_profile().unwrap().name, "stratix10");
        c.device = "arria10".into();
        assert_eq!(c.device_profile().unwrap().fmax_mhz, 167.0);
        c.device = "nope".into();
        assert!(c.device_profile().is_err());
    }

    #[test]
    fn design_defaults_per_device() {
        let mut c = RunConfig::default();
        assert_eq!(c.design_params().unwrap().vec_size, 16);
        c.device = "arria10".into();
        assert_eq!(c.design_params().unwrap().vec_size, 32);
        c.design = Some(DesignParams::new(8, 4));
        assert_eq!(c.design_params().unwrap().lane_num, 4);
    }

    #[test]
    fn artifact_naming() {
        let c = RunConfig::default();
        assert_eq!(c.artifact_name(4), "alexnet_b4_jnp");
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ffcnn_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        let mut c = RunConfig::default();
        c.model = "resnet50".into();
        c.save(&p).unwrap();
        let d = RunConfig::load(&p).unwrap();
        assert_eq!(d.model, "resnet50");
    }

    #[test]
    fn bad_overlap_rejected() {
        let j = Json::parse(r#"{"overlap":"sometimes"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }
}
