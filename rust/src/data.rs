//! Synthetic data + workload generation (DESIGN.md §2 substitution for
//! ImageNet: the paper checks functional correctness, not accuracy).
//!
//! Deterministic, seeded, dependency-free: a SplitMix64 PRNG drives
//! both image synthesis and Poisson request arrivals so every run —
//! tests, benches, EXPERIMENTS.md — is reproducible bit-for-bit.

/// SplitMix64 — tiny deterministic PRNG (public-domain constants).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard-normal-ish f32 (sum of 4 uniforms, CLT; adequate for
    /// synthetic pixels).
    pub fn next_gauss(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.next_f32()).sum();
        (s - 2.0) * (3.0f32).sqrt()
    }

    /// Exponential inter-arrival with the given rate (events/sec).
    pub fn next_exp(&mut self, rate: f64) -> f64 {
        let u = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        -(1.0 - u).ln() / rate
    }
}

/// A synthetic image batch in NCHW layout, values ~N(0, 0.1²) — the
/// same distribution `python/compile/aot.py::make_input` uses.
pub fn synth_images(
    batch: usize,
    chw: (usize, usize, usize),
    seed: u64,
) -> Vec<f32> {
    let (c, h, w) = chw;
    let mut rng = Rng::new(seed);
    (0..batch * c * h * w)
        .map(|_| rng.next_gauss() * 0.1)
        .collect()
}

/// Assemble per-image rows into one flat NCHW batch through the wide
/// gather kernel — the staging shape `submit_batch` wants.  Every row
/// must have the same length; the result is `rows.len() * numel`
/// floats.  Benches use this to build batch payloads without paying a
/// per-element copy in setup.
pub fn flat_batch(rows: &[Vec<f32>]) -> Vec<f32> {
    let numel = rows.first().map(|r| r.len()).unwrap_or(0);
    debug_assert!(
        rows.iter().all(|r| r.len() == numel),
        "ragged rows cannot form a flat batch"
    );
    let mut flat = vec![0.0f32; rows.len() * numel];
    crate::util::vecops::gather_rows(
        &mut flat,
        rows.iter().map(|r| r.as_slice()),
    );
    flat
}

/// One inference request in a generated workload trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    pub id: u64,
    /// Arrival time offset from trace start, seconds.
    pub arrival_s: f64,
    /// Images in this arrival (1 = a single-image request; > 1 = one
    /// whole batch submitted at once, which the trace replayer routes
    /// through `submit_batch` under the serving `ShardPolicy`).
    pub batch: usize,
}

/// Poisson open-loop arrival trace: `n` requests at `rate` req/s.
pub fn poisson_trace(n: usize, rate: f64, seed: u64) -> Vec<TraceRequest> {
    poisson_batch_trace(n, rate, 1, seed)
}

/// Poisson open-loop trace of whole-batch arrivals: `n` requests at
/// `rate` req/s, each carrying `batch` images — the E4 workload for
/// comparing `ShardPolicy` under open-loop load.
pub fn poisson_batch_trace(
    n: usize,
    rate: f64,
    batch: usize,
    seed: u64,
) -> Vec<TraceRequest> {
    let batch = batch.max(1);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n as u64)
        .map(|id| {
            t += rng.next_exp(rate);
            TraceRequest { id, arrival_s: t, batch }
        })
        .collect()
}

/// Bursty open-loop trace: a Poisson process whose instantaneous rate
/// swings sinusoidally between `base_rate` and `base_rate * burst`
/// req/s over a `period_s` cycle — a compressed diurnal load curve,
/// the robustness workload `coordinator::sim`'s `bursty_arrivals`
/// scenario replays.  Deterministic for a fixed seed, like every
/// generator here; `burst` clamps to >= 1 and `period_s` to a sane
/// positive floor.
pub fn bursty_trace(
    n: usize,
    base_rate: f64,
    burst: f64,
    period_s: f64,
    seed: u64,
) -> Vec<TraceRequest> {
    let burst = burst.max(1.0);
    let period = period_s.max(1e-9);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n as u64)
        .map(|id| {
            let phase = (std::f64::consts::TAU * t / period).sin();
            let rate = base_rate * (1.0 + (burst - 1.0) * 0.5 * (1.0 + phase));
            t += rng.next_exp(rate);
            TraceRequest { id, arrival_s: t, batch: 1 }
        })
        .collect()
}

/// Closed-loop trace: all requests available at t=0 (max-throughput).
pub fn burst_trace(n: usize) -> Vec<TraceRequest> {
    (0..n as u64)
        .map(|id| TraceRequest { id, arrival_s: 0.0, batch: 1 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seed_sensitivity() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range_and_varied() {
        let mut r = Rng::new(3);
        let xs: Vec<f32> = (0..1000).map(|_| r.next_f32()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean: f32 = xs.iter().sum::<f32>() / 1000.0;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn gauss_roughly_standard() {
        let mut r = Rng::new(5);
        let xs: Vec<f32> = (0..4000).map(|_| r.next_gauss()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.08, "mean={mean}");
        assert!((var - 1.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn synth_images_shape_and_determinism() {
        let a = synth_images(2, (3, 4, 4), 9);
        let b = synth_images(2, (3, 4, 4), 9);
        assert_eq!(a.len(), 2 * 3 * 4 * 4);
        assert_eq!(a, b);
        assert_ne!(a, synth_images(2, (3, 4, 4), 10));
    }

    #[test]
    fn flat_batch_concatenates_rows_in_order() {
        let rows = vec![
            synth_images(1, (1, 2, 2), 1),
            synth_images(1, (1, 2, 2), 2),
            synth_images(1, (1, 2, 2), 3),
        ];
        let flat = flat_batch(&rows);
        assert_eq!(flat.len(), 12);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(&flat[i * 4..(i + 1) * 4], &row[..], "row {i}");
        }
        assert!(flat_batch(&[]).is_empty());
    }

    #[test]
    fn poisson_trace_monotone_and_rate() {
        let tr = poisson_trace(2000, 100.0, 11);
        assert!(tr.windows(2).all(|w| w[1].arrival_s >= w[0].arrival_s));
        let span = tr.last().unwrap().arrival_s;
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() / 100.0 < 0.15, "rate={rate}");
    }

    #[test]
    fn burst_trace_all_at_zero() {
        let tr = burst_trace(5);
        assert_eq!(tr.len(), 5);
        assert!(tr.iter().all(|r| r.arrival_s == 0.0));
        assert!(tr.iter().all(|r| r.batch == 1));
    }

    #[test]
    fn bursty_trace_deterministic_and_monotone() {
        let a = bursty_trace(200, 100.0, 8.0, 0.5, 21);
        let b = bursty_trace(200, 100.0, 8.0, 0.5, 21);
        assert_eq!(a, b);
        assert_ne!(a, bursty_trace(200, 100.0, 8.0, 0.5, 22));
        assert!(a.windows(2).all(|w| w[1].arrival_s >= w[0].arrival_s));
        assert!(a.iter().all(|r| r.batch == 1));
    }

    #[test]
    fn bursty_trace_rate_between_base_and_peak() {
        // The modulated rate averages between the trough and the peak,
        // so the realized throughput must land strictly inside them.
        let tr = bursty_trace(4000, 100.0, 8.0, 0.5, 13);
        let span = tr.last().unwrap().arrival_s;
        let rate = 4000.0 / span;
        assert!(rate > 100.0 && rate < 800.0, "rate={rate}");
        // burst <= 1 degrades to plain Poisson at base_rate.
        let flat = bursty_trace(2000, 100.0, 1.0, 0.5, 11);
        let frate = 2000.0 / flat.last().unwrap().arrival_s;
        assert!((frate - 100.0).abs() / 100.0 < 0.15, "frate={frate}");
    }

    #[test]
    fn batched_trace_matches_single_image_arrivals() {
        // Same seed, same arrival process — the batched variant only
        // changes what each arrival carries (and clamps batch >= 1).
        let singles = poisson_trace(50, 80.0, 3);
        let batched = poisson_batch_trace(50, 80.0, 16, 3);
        assert_eq!(singles.len(), batched.len());
        for (s, b) in singles.iter().zip(&batched) {
            assert_eq!(s.arrival_s, b.arrival_s);
            assert_eq!(s.id, b.id);
            assert_eq!(s.batch, 1);
            assert_eq!(b.batch, 16);
        }
        assert!(poisson_batch_trace(3, 10.0, 0, 1)
            .iter()
            .all(|t| t.batch == 1));
    }
}
