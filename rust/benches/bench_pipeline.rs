//! Bench E3: the FPGA simulator itself — analytic model vs token-level
//! pipeline simulation, across models, devices and channel depths.
//!
//! Prints the layer-breakdown experiment, then times both simulators
//! (the token sim must stay fast enough for interactive DSE).

use std::time::Duration;

use ffcnn::fpga::device::{ARRIA10, STRATIX10};
use ffcnn::fpga::pipeline::{simulate_tokens, simulate_tokens_exact};
use ffcnn::fpga::timing::{
    ffcnn_arria10_params, ffcnn_stratix10_params, simulate_model,
    OverlapPolicy,
};
use ffcnn::models;
use ffcnn::util::bench::Bench;

fn main() {
    // Experiment output: fusion bandwidth saving + model agreement.
    for (m, d, p) in [
        (models::alexnet(), &STRATIX10, ffcnn_stratix10_params()),
        (models::alexnet(), &ARRIA10, ffcnn_arria10_params()),
        (models::resnet50(), &STRATIX10, ffcnn_stratix10_params()),
    ] {
        let ana = simulate_model(&m, d, &p, 1, OverlapPolicy::WithinGroup);
        let tok = simulate_tokens(&m, d, &p, 1);
        println!(
            "{:<10} {:<12} analytic {:>8.2} ms | token {:>8.2} ms | \
             fusion saves {:>4.0}% DDR",
            m.name,
            d.name,
            ana.time_per_image_ms(),
            tok.time_ms(),
            ana.fusion_traffic_saving() * 100.0
        );
    }

    let mut b = Bench::new("pipeline").with_budget(Duration::from_secs(4));
    let alex = models::alexnet();
    let resnet = models::resnet50();
    let p = ffcnn_stratix10_params();

    b.run("analytic_alexnet", || {
        simulate_model(&alex, &STRATIX10, &p, 1, OverlapPolicy::WithinGroup)
            .total_cycles
    });
    b.run("analytic_resnet50", || {
        simulate_model(&resnet, &STRATIX10, &p, 1, OverlapPolicy::WithinGroup)
            .total_cycles
    });
    b.run("token_alexnet", || {
        simulate_tokens(&alex, &STRATIX10, &p, 1).total_cycles
    });
    b.run("token_resnet50", || {
        simulate_tokens(&resnet, &STRATIX10, &p, 1).total_cycles
    });
    // The O(tokens) oracle, for the fast-path speedup headline.
    b.run("token_alexnet_exact_oracle", || {
        simulate_tokens_exact(&alex, &STRATIX10, &p, 1).total_cycles
    });

    // Channel-depth ablation: deeper channels cost sim time linearly?
    for depth in [64usize, 512, 2048] {
        let mut pd = p;
        pd.channel_depth = depth;
        b.run(&format!("token_alexnet_depth{depth}"), || {
            simulate_tokens(&alex, &STRATIX10, &pd, 1).total_cycles
        });
    }
    b.finish();
}
