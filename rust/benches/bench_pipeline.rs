//! Bench E3: the FPGA simulator itself — analytic model vs token-level
//! pipeline simulation, across models, devices, channel depths and
//! overlap policies.
//!
//! Prints the layer-breakdown experiment, times both simulators (the
//! token sim must stay fast enough for interactive DSE), and writes
//! `BENCH_pipeline.json` with the PR-2 acceptance numbers: predicted
//! overlap-on vs overlap-off latency (VGG-16 b16 and the memory-bound
//! b1 rows) and the measured fast-vs-exact simulator speedup for the
//! overlapped stream.

use std::path::Path;
use std::time::Duration;

use ffcnn::fpga::device::{ARRIA10, STRATIX10};
use ffcnn::fpga::pipeline::{PipelineSim, Simulator};
use ffcnn::fpga::timing::{
    ffcnn_arria10_params, ffcnn_stratix10_params, simulate_model,
    DesignParams, OverlapPolicy,
};
use ffcnn::models::{self, Model};
use ffcnn::util::bench::Bench;
use ffcnn::util::Json;

/// Token-level simulation through the facade (STRATIX10 unless noted).
fn tok(
    m: &Model,
    p: &DesignParams,
    batch: usize,
    pol: OverlapPolicy,
    exact: bool,
) -> PipelineSim {
    Simulator::new(m, &STRATIX10, *p).policy(pol).exact(exact).run(batch)
}

fn main() {
    // `--check` dry-run: validate the previously written artifact's
    // schema and exit (the CI drift gate).
    if ffcnn::util::bench::check_mode(Path::new("BENCH_pipeline.json")) {
        return;
    }
    // Experiment output: fusion bandwidth saving + model agreement.
    for (m, d, p) in [
        (models::alexnet(), &STRATIX10, ffcnn_stratix10_params()),
        (models::alexnet(), &ARRIA10, ffcnn_arria10_params()),
        (models::resnet50(), &STRATIX10, ffcnn_stratix10_params()),
    ] {
        let ana = simulate_model(&m, d, &p, 1, OverlapPolicy::WithinGroup);
        let tok = Simulator::new(&m, d, p).run(1);
        println!(
            "{:<10} {:<12} analytic {:>8.2} ms | token {:>8.2} ms | \
             fusion saves {:>4.0}% DDR",
            m.name,
            d.name,
            ana.time_per_image_ms(),
            tok.time_ms(),
            ana.fusion_traffic_saving() * 100.0
        );
    }

    // Overlap ablation at token granularity (the PR-2 headline).
    let p = ffcnn_stratix10_params();
    println!("\ncross-group overlap (token sim, stratix10):");
    for (name, m, batch) in [
        ("alexnet", models::alexnet(), 1usize),
        ("vgg16", models::vgg16(), 1),
        ("vgg16", models::vgg16(), 16),
    ] {
        let within = tok(&m, &p, batch, OverlapPolicy::WithinGroup, false);
        let full = tok(&m, &p, batch, OverlapPolicy::Full, false);
        println!(
            "  {name:<8} b{batch:<3} within {:>12} cy | full {:>12} cy | \
             overlap saves {:>6.3}%",
            within.total_cycles,
            full.total_cycles,
            (within.total_cycles as f64 - full.total_cycles as f64)
                / within.total_cycles as f64
                * 100.0
        );
    }

    let mut b = Bench::new("pipeline").with_budget(Duration::from_secs(4));
    let alex = models::alexnet();
    let resnet = models::resnet50();
    let vgg = models::vgg16();

    b.run("analytic_alexnet", || {
        simulate_model(&alex, &STRATIX10, &p, 1, OverlapPolicy::WithinGroup)
            .total_cycles
    });
    b.run("analytic_resnet50", || {
        simulate_model(&resnet, &STRATIX10, &p, 1, OverlapPolicy::WithinGroup)
            .total_cycles
    });
    b.run("token_alexnet", || {
        tok(&alex, &p, 1, OverlapPolicy::WithinGroup, false).total_cycles
    });
    b.run("token_resnet50", || {
        tok(&resnet, &p, 1, OverlapPolicy::WithinGroup, false).total_cycles
    });
    b.run("token_alexnet_overlap_full", || {
        tok(&alex, &p, 1, OverlapPolicy::Full, false).total_cycles
    });
    // The O(tokens) oracle, for the fast-path speedup headline.
    b.run("token_alexnet_exact_oracle", || {
        tok(&alex, &p, 1, OverlapPolicy::WithinGroup, true).total_cycles
    });

    // Channel-depth ablation: deeper channels cost sim time linearly?
    for depth in [64usize, 512, 2048] {
        let mut pd = p;
        pd.channel_depth = depth;
        b.run(&format!("token_alexnet_depth{depth}"), || {
            tok(&alex, &pd, 1, OverlapPolicy::WithinGroup, false)
                .total_cycles
        });
    }

    // ---- overlapped fast path vs O(tokens) stream oracle ------------
    // VGG-16 b16 under Full: the fast path leaps steady interiors; the
    // exact oracle walks every one of the ~45M tokens, so it runs once.
    let vgg_full_fast = tok(&vgg, &p, 16, OverlapPolicy::Full, false);
    let vgg_full_within =
        tok(&vgg, &p, 16, OverlapPolicy::WithinGroup, false);
    let fast_ns = b
        .run("token_vgg16_b16_overlap_full_fast", || {
            tok(&vgg, &p, 16, OverlapPolicy::Full, false).total_cycles
        })
        .median_ns;
    b.warmup = 0;
    b.min_iters = 1;
    b.max_iters = 1;
    let exact_ns = b
        .run("token_vgg16_b16_overlap_full_exact", || {
            tok(&vgg, &p, 16, OverlapPolicy::Full, true).total_cycles
        })
        .median_ns;
    let sim_speedup = exact_ns as f64 / fast_ns as f64;
    println!(
        "\nVGG-16 b16 overlapped sim: fast {:.2} ms vs exact {:.1} ms \
         -> {:.0}x",
        fast_ns as f64 / 1e6,
        exact_ns as f64 / 1e6,
        sim_speedup
    );

    // b1 rows: where the FC weight streams are exposed and overlap
    // buys real latency.
    let v1_full = tok(&vgg, &p, 1, OverlapPolicy::Full, false);
    let v1_within = tok(&vgg, &p, 1, OverlapPolicy::WithinGroup, false);
    let a1_full = tok(&alex, &p, 1, OverlapPolicy::Full, false);
    let a1_within = tok(&alex, &p, 1, OverlapPolicy::WithinGroup, false);

    // b16 is compute-bound everywhere, so the overlap win there is
    // rounding-thin (strictly below today, but gate only on <= so a
    // benign leap-rounding change cannot flip a 2-cycle sign out of
    // 1.4B and redden CI); the material wins are the b1 rows, gated
    // strictly.
    assert!(
        vgg_full_fast.total_cycles <= vgg_full_within.total_cycles,
        "overlap-on must not exceed overlap-off on vgg16 b16: {} vs {}",
        vgg_full_fast.total_cycles,
        vgg_full_within.total_cycles
    );
    assert!(
        v1_full.total_cycles < v1_within.total_cycles,
        "overlap-on must beat overlap-off on vgg16 b1: {} vs {}",
        v1_full.total_cycles,
        v1_within.total_cycles
    );
    assert!(
        a1_full.total_cycles < a1_within.total_cycles,
        "overlap-on must beat overlap-off on alexnet b1: {} vs {}",
        a1_full.total_cycles,
        a1_within.total_cycles
    );

    b.save_json(
        Path::new("BENCH_pipeline.json"),
        vec![
            (
                "pipeline_sim_fast_vs_exact_speedup",
                Json::num(sim_speedup),
            ),
            (
                "vgg16_b16_overlap_on_ms",
                Json::num(vgg_full_fast.time_ms()),
            ),
            (
                "vgg16_b16_overlap_off_ms",
                Json::num(vgg_full_within.time_ms()),
            ),
            (
                "vgg16_b16_overlap_on_cycles",
                Json::num(vgg_full_fast.total_cycles as f64),
            ),
            (
                "vgg16_b16_overlap_off_cycles",
                Json::num(vgg_full_within.total_cycles as f64),
            ),
            ("vgg16_b1_overlap_on_ms", Json::num(v1_full.time_ms())),
            ("vgg16_b1_overlap_off_ms", Json::num(v1_within.time_ms())),
            ("alexnet_b1_overlap_on_ms", Json::num(a1_full.time_ms())),
            (
                "alexnet_b1_overlap_off_ms",
                Json::num(a1_within.time_ms()),
            ),
        ],
    )
    .expect("writing BENCH_pipeline.json");
    println!(
        "wrote BENCH_pipeline.json (sim speedup {sim_speedup:.0}x, \
         vgg16 b16 overlap {} < {} cycles)",
        vgg_full_fast.total_cycles, vgg_full_within.total_cycles
    );
    b.finish();
}
