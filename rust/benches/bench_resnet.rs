//! Bench E1: ResNet-50 end-to-end — simulated FPGA time on both
//! devices across batch sizes, plus real PJRT execution if artifacts
//! are present.

use std::time::Duration;

use ffcnn::config::default_artifacts_dir;
use ffcnn::data;
use ffcnn::fpga::device::{ARRIA10, STRATIX10};
use ffcnn::fpga::timing::{
    ffcnn_arria10_params, ffcnn_stratix10_params, simulate_model,
    OverlapPolicy,
};
use ffcnn::models;
use ffcnn::runtime::Engine;
use ffcnn::util::bench::Bench;

fn main() {
    let model = models::resnet50();

    // Experiment output: the E1 table (simulated classification time).
    println!(
        "{:<12}{:>8}{:>12}{:>10}",
        "device", "batch", "ms/image", "GOPS"
    );
    for (d, p) in [
        (&ARRIA10, ffcnn_arria10_params()),
        (&STRATIX10, ffcnn_stratix10_params()),
    ] {
        for batch in [1usize, 4] {
            let t =
                simulate_model(&model, d, &p, batch, OverlapPolicy::WithinGroup);
            println!(
                "{:<12}{:>8}{:>12.2}{:>10.1}",
                d.name,
                batch,
                t.time_per_image_ms(),
                t.gops()
            );
        }
    }

    let mut b = Bench::new("resnet").with_budget(Duration::from_secs(10));
    let p = ffcnn_stratix10_params();
    b.run("simulate_b1", || {
        simulate_model(&model, &STRATIX10, &p, 1, OverlapPolicy::WithinGroup)
            .total_cycles
    });

    // Real numerics through PJRT (skipped when artifacts are absent).
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let engine = Engine::open(&dir).unwrap();
        if engine.warm("resnet50_b1_jnp").is_ok() {
            let input = data::synth_images(1, model.in_shape, 3);
            b.run("pjrt_execute_b1", || {
                engine.execute("resnet50_b1_jnp", &input).unwrap().len()
            });
        }
    } else {
        println!("(no artifacts; skipping PJRT benches)");
    }
    b.finish();
}
