//! Bench E2: full design-space exploration on both devices through
//! the `Plan -> Deployment` facade, printing the chosen points (the
//! paper's "design space fully explored") and timing the sweep.
//!
//! The acceptance benchmark for the closed-form fast path lives here:
//! VGG-16 at batch 16 swept with the pipeline simulator's fast path
//! vs the O(tokens) exact oracle.  The suite (and the measured
//! speedup), plus the best-per-precision rows of the precision axis,
//! is written to `BENCH_dse.json` so the numbers are tracked across
//! PRs.

use std::path::Path;
use std::time::Duration;

use ffcnn::fpga::device::{ARRIA10, STRATIX10, STRATIXV};
use ffcnn::fpga::dse::{self, Fidelity, SweepSpace};
use ffcnn::fpga::timing::{OverlapPolicy, Precision};
use ffcnn::models;
use ffcnn::util::bench::Bench;
use ffcnn::util::Json;

/// The classic analytic `(vec, lane)` sweep through the one canonical
/// engine (`explore_space`, what `Deployment::sweep` calls).
fn sweep_default(
    model: &ffcnn::models::Model,
    device: &ffcnn::fpga::device::DeviceProfile,
    batch: usize,
    fidelity: Fidelity,
) -> Vec<dse::DesignPoint> {
    dse::explore_space(model, device, batch, fidelity, &SweepSpace::default())
}

fn main() {
    // `--check` dry-run: validate the previously written artifact's
    // schema and exit (the CI drift gate).
    if ffcnn::util::bench::check_mode(Path::new("BENCH_dse.json")) {
        return;
    }
    let model = models::alexnet();

    for device in [&ARRIA10, &STRATIX10, &STRATIXV] {
        let pts = sweep_default(&model, device, 1, Fidelity::Analytic);
        let lat = dse::best_latency(&pts).unwrap();
        let den = dse::best_density(&pts).unwrap();
        println!(
            "{:<12} {:>3} feasible/{:>3} | latency-opt vec={} lane={} \
             ({:.2} ms) | density-opt vec={} lane={} ({:.3} GOPS/DSP)",
            device.name,
            pts.iter().filter(|p| p.feasible).count(),
            pts.len(),
            lat.params.vec_size,
            lat.params.lane_num,
            lat.time_ms,
            den.params.vec_size,
            den.params.lane_num,
            den.gops_per_dsp
        );
    }

    // Extended sweep: overlap on/off x channel depth (PR-2 dimension).
    let space = SweepSpace::with_overlap_and_depth();
    let pts = dse::explore_space(
        &model,
        &STRATIX10,
        1,
        Fidelity::PipelineFast,
        &space,
    );
    let best = dse::best_latency(&pts).unwrap();
    let overlap_wins = pts
        .chunks(2)
        .filter(|pair| {
            // The stat depends on overlaps being the innermost grid
            // dimension in [WithinGroup, Full] order — fail loudly if
            // the sweep space ever reshapes instead of miscounting.
            assert_eq!(pair[0].overlap, OverlapPolicy::WithinGroup);
            assert_eq!(pair[1].overlap, OverlapPolicy::Full);
            pair[0].feasible && pair[1].time_ms < pair[0].time_ms
        })
        .count();
    println!(
        "overlap x depth sweep: {} points | latency-opt vec={} lane={} \
         depth={} {:?} ({:.2} ms) | Full beats WithinGroup at \
         {overlap_wins} feasible points",
        pts.len(),
        best.params.vec_size,
        best.params.lane_num,
        best.params.channel_depth,
        best.overlap,
        best.time_ms
    );
    assert!(matches!(
        best.overlap,
        OverlapPolicy::Full | OverlapPolicy::WithinGroup
    ));

    // ---- precision axis (ROADMAP: DSE over precision) ---------------
    let ppts = dse::explore_space(
        &model,
        &STRATIX10,
        1,
        Fidelity::Analytic,
        &SweepSpace::with_precision(),
    );
    let lat_per = dse::best_latency_per_precision(&ppts);
    let den_per = dse::best_density_per_precision(&ppts);
    println!("\nprecision sweep (alexnet, stratix10):");
    for ((prec, lp), (_, dp)) in lat_per.iter().zip(&den_per) {
        println!(
            "  {:<10} best latency vec={:<3} lane={:<3} {:>8.2} ms | \
             best density {:.3} GOPS/DSP",
            format!("{prec:?}"),
            lp.params.vec_size,
            lp.params.lane_num,
            lp.time_ms,
            dp.gops_per_dsp
        );
    }
    let lat_ms = |prec: Precision| {
        lat_per
            .iter()
            .find(|(q, _)| *q == prec)
            .map(|(_, p)| p.time_ms)
            .unwrap_or(f64::NAN)
    };
    let dens = |prec: Precision| {
        den_per
            .iter()
            .find(|(q, _)| *q == prec)
            .map(|(_, p)| p.gops_per_dsp)
            .unwrap_or(f64::NAN)
    };
    // The packing must pay: fixed point strictly improves the density
    // optimum (DSPs shrink; time never grows on the same grid).
    assert!(
        dens(Precision::Fixed8) > dens(Precision::Fixed16)
            && dens(Precision::Fixed16) > dens(Precision::Fp32),
        "density optima must improve with packing: {} / {} / {}",
        dens(Precision::Fp32),
        dens(Precision::Fixed16),
        dens(Precision::Fixed8)
    );

    let mut b = Bench::new("dse").with_budget(Duration::from_secs(4));
    b.run("explore_alexnet_stratix10", || {
        sweep_default(&model, &STRATIX10, 1, Fidelity::Analytic).len()
    });
    b.run("explore_alexnet_overlap_depth_space", || {
        dse::explore_space(
            &model,
            &STRATIX10,
            1,
            Fidelity::PipelineFast,
            &SweepSpace::with_overlap_and_depth(),
        )
        .len()
    });
    b.run("explore_alexnet_precision_space", || {
        dse::explore_space(
            &model,
            &STRATIX10,
            1,
            Fidelity::Analytic,
            &SweepSpace::with_precision(),
        )
        .len()
    });
    b.run("explore_alexnet_arria10", || {
        sweep_default(&model, &ARRIA10, 1, Fidelity::Analytic).len()
    });
    let resnet = models::resnet50();
    b.run("explore_resnet50_stratix10", || {
        sweep_default(&resnet, &STRATIX10, 1, Fidelity::Analytic).len()
    });
    b.run("pareto_extraction", || {
        let pts = sweep_default(&model, &STRATIX10, 1, Fidelity::Analytic);
        dse::pareto(&pts).len()
    });

    // ---- fast path vs token-exact oracle: VGG-16, batch 16 ----------
    // The fast sweep gets normal statistics; the exact sweep is run
    // once (it walks hundreds of millions of tokens per point).
    let vgg = models::vgg16();
    let fast_ns = b
        .run("explore_vgg16_b16_pipeline_fast", || {
            sweep_default(&vgg, &STRATIX10, 16, Fidelity::PipelineFast)
                .len()
        })
        .median_ns;
    b.warmup = 0;
    b.min_iters = 1;
    b.max_iters = 1;
    let exact_ns = b
        .run("explore_vgg16_b16_pipeline_exact", || {
            sweep_default(&vgg, &STRATIX10, 16, Fidelity::PipelineExact)
                .len()
        })
        .median_ns;
    let speedup = exact_ns as f64 / fast_ns as f64;
    println!(
        "\nVGG-16 b16 sweep: fast {:.1} ms vs exact {:.1} ms -> {:.1}x",
        fast_ns as f64 / 1e6,
        exact_ns as f64 / 1e6,
        speedup
    );

    b.save_json(
        Path::new("BENCH_dse.json"),
        vec![
            ("dse_vgg16_b16_speedup_vs_exact", Json::num(speedup)),
            ("dse_vgg16_b16_fast_ms", Json::num(fast_ns as f64 / 1e6)),
            ("dse_vgg16_b16_exact_ms", Json::num(exact_ns as f64 / 1e6)),
            ("dse_best_ms_fp32", Json::num(lat_ms(Precision::Fp32))),
            ("dse_best_ms_fixed16", Json::num(lat_ms(Precision::Fixed16))),
            ("dse_best_ms_fixed8", Json::num(lat_ms(Precision::Fixed8))),
            ("dse_best_density_fp32", Json::num(dens(Precision::Fp32))),
            (
                "dse_best_density_fixed16",
                Json::num(dens(Precision::Fixed16)),
            ),
            (
                "dse_best_density_fixed8",
                Json::num(dens(Precision::Fixed8)),
            ),
        ],
    )
    .expect("writing BENCH_dse.json");
    println!("wrote BENCH_dse.json (speedup {speedup:.1}x)");
    b.finish();
}
