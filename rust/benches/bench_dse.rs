//! Bench E2: full design-space exploration on both devices, printing
//! the chosen points (the paper's "design space fully explored") and
//! timing the sweep.
//!
//! The acceptance benchmark for the closed-form fast path lives here:
//! VGG-16 at batch 16 swept with the pipeline simulator's fast path
//! vs the O(tokens) exact oracle.  The suite (and the measured
//! speedup) is written to `BENCH_dse.json` so the number is tracked
//! across PRs.

use std::path::Path;
use std::time::Duration;

use ffcnn::fpga::device::{ARRIA10, STRATIX10, STRATIXV};
use ffcnn::fpga::dse::{self, Fidelity, SweepSpace};
use ffcnn::fpga::timing::OverlapPolicy;
use ffcnn::models;
use ffcnn::util::bench::Bench;
use ffcnn::util::Json;

fn main() {
    let model = models::alexnet();

    for device in [&ARRIA10, &STRATIX10, &STRATIXV] {
        let pts = dse::explore(&model, device, 1);
        let lat = dse::best_latency(&pts).unwrap();
        let den = dse::best_density(&pts).unwrap();
        println!(
            "{:<12} {:>3} feasible/{:>3} | latency-opt vec={} lane={} \
             ({:.2} ms) | density-opt vec={} lane={} ({:.3} GOPS/DSP)",
            device.name,
            pts.iter().filter(|p| p.feasible).count(),
            pts.len(),
            lat.params.vec_size,
            lat.params.lane_num,
            lat.time_ms,
            den.params.vec_size,
            den.params.lane_num,
            den.gops_per_dsp
        );
    }

    // Extended sweep: overlap on/off x channel depth (PR-2 dimension).
    let space = SweepSpace::with_overlap_and_depth();
    let pts = dse::explore_space(
        &model,
        &STRATIX10,
        1,
        Fidelity::PipelineFast,
        &space,
    );
    let best = dse::best_latency(&pts).unwrap();
    let overlap_wins = pts
        .chunks(2)
        .filter(|pair| {
            // The stat depends on overlaps being the innermost grid
            // dimension in [WithinGroup, Full] order — fail loudly if
            // the sweep space ever reshapes instead of miscounting.
            assert_eq!(pair[0].overlap, OverlapPolicy::WithinGroup);
            assert_eq!(pair[1].overlap, OverlapPolicy::Full);
            pair[0].feasible && pair[1].time_ms < pair[0].time_ms
        })
        .count();
    println!(
        "overlap x depth sweep: {} points | latency-opt vec={} lane={} \
         depth={} {:?} ({:.2} ms) | Full beats WithinGroup at \
         {overlap_wins} feasible points",
        pts.len(),
        best.params.vec_size,
        best.params.lane_num,
        best.params.channel_depth,
        best.overlap,
        best.time_ms
    );
    assert!(matches!(
        best.overlap,
        OverlapPolicy::Full | OverlapPolicy::WithinGroup
    ));

    let mut b = Bench::new("dse").with_budget(Duration::from_secs(4));
    b.run("explore_alexnet_stratix10", || {
        dse::explore(&model, &STRATIX10, 1).len()
    });
    b.run("explore_alexnet_overlap_depth_space", || {
        dse::explore_space(
            &model,
            &STRATIX10,
            1,
            Fidelity::PipelineFast,
            &SweepSpace::with_overlap_and_depth(),
        )
        .len()
    });
    b.run("explore_alexnet_arria10", || {
        dse::explore(&model, &ARRIA10, 1).len()
    });
    let resnet = models::resnet50();
    b.run("explore_resnet50_stratix10", || {
        dse::explore(&resnet, &STRATIX10, 1).len()
    });
    b.run("pareto_extraction", || {
        let pts = dse::explore(&model, &STRATIX10, 1);
        dse::pareto(&pts).len()
    });

    // ---- fast path vs token-exact oracle: VGG-16, batch 16 ----------
    // The fast sweep gets normal statistics; the exact sweep is run
    // once (it walks hundreds of millions of tokens per point).
    let vgg = models::vgg16();
    let fast_ns = b
        .run("explore_vgg16_b16_pipeline_fast", || {
            dse::explore_with(&vgg, &STRATIX10, 16, Fidelity::PipelineFast)
                .len()
        })
        .median_ns;
    b.warmup = 0;
    b.min_iters = 1;
    b.max_iters = 1;
    let exact_ns = b
        .run("explore_vgg16_b16_pipeline_exact", || {
            dse::explore_with(&vgg, &STRATIX10, 16, Fidelity::PipelineExact)
                .len()
        })
        .median_ns;
    let speedup = exact_ns as f64 / fast_ns as f64;
    println!(
        "\nVGG-16 b16 sweep: fast {:.1} ms vs exact {:.1} ms -> {:.1}x",
        fast_ns as f64 / 1e6,
        exact_ns as f64 / 1e6,
        speedup
    );

    b.save_json(
        Path::new("BENCH_dse.json"),
        vec![
            ("dse_vgg16_b16_speedup_vs_exact", Json::num(speedup)),
            ("dse_vgg16_b16_fast_ms", Json::num(fast_ns as f64 / 1e6)),
            ("dse_vgg16_b16_exact_ms", Json::num(exact_ns as f64 / 1e6)),
        ],
    )
    .expect("writing BENCH_dse.json");
    println!("wrote BENCH_dse.json (speedup {speedup:.1}x)");
    b.finish();
}
