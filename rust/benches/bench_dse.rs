//! Bench E2: full design-space exploration on both devices, printing
//! the chosen points (the paper's "design space fully explored") and
//! timing the sweep.

use std::time::Duration;

use ffcnn::fpga::device::{ARRIA10, STRATIX10, STRATIXV};
use ffcnn::fpga::dse;
use ffcnn::models;
use ffcnn::util::bench::Bench;

fn main() {
    let model = models::alexnet();

    for device in [&ARRIA10, &STRATIX10, &STRATIXV] {
        let pts = dse::explore(&model, device, 1);
        let lat = dse::best_latency(&pts).unwrap();
        let den = dse::best_density(&pts).unwrap();
        println!(
            "{:<12} {:>3} feasible/{:>3} | latency-opt vec={} lane={} \
             ({:.2} ms) | density-opt vec={} lane={} ({:.3} GOPS/DSP)",
            device.name,
            pts.iter().filter(|p| p.feasible).count(),
            pts.len(),
            lat.params.vec_size,
            lat.params.lane_num,
            lat.time_ms,
            den.params.vec_size,
            den.params.lane_num,
            den.gops_per_dsp
        );
    }

    let mut b = Bench::new("dse").with_budget(Duration::from_secs(4));
    b.run("explore_alexnet_stratix10", || {
        dse::explore(&model, &STRATIX10, 1).len()
    });
    b.run("explore_alexnet_arria10", || {
        dse::explore(&model, &ARRIA10, 1).len()
    });
    let resnet = models::resnet50();
    b.run("explore_resnet50_stratix10", || {
        dse::explore(&resnet, &STRATIX10, 1).len()
    });
    b.run("pareto_extraction", || {
        let pts = dse::explore(&model, &STRATIX10, 1);
        dse::pareto(&pts).len()
    });
    b.finish();
}
