//! Bench F1: regenerate Fig. 1 (weights/ops distribution) for VGG-11
//! (the paper's model) plus AlexNet and ResNet-50.

use std::time::Duration;

use ffcnn::models;
use ffcnn::report::{fig1_distribution, render_fig1};
use ffcnn::util::bench::Bench;

fn main() {
    // The experiment itself.
    println!("{}", render_fig1(&models::vgg11()));

    let mut b = Bench::new("fig1").with_budget(Duration::from_secs(2));
    for name in ["vgg11", "alexnet", "resnet50"] {
        let m = models::by_name(name).unwrap();
        b.run(&format!("distribution_{name}"), || fig1_distribution(&m));
    }
    b.finish();
}
