//! Bench: closed-loop serving control (`coordinator::control`).
//!
//! Two kinds of rows in `BENCH_control.json`:
//!
//! - **Measured** (`b.run`): the controller's own overhead — a tick
//!   with a full latency window, and the admission check on the
//!   submit hot path.  Both are nanosecond-scale; the rows pin that
//!   the closed loop costs nothing the coordinator would notice.
//! - **Headline** (extras): the tentpole experiment, run in *virtual*
//!   time (deterministic, engine-less, CI-fast) via
//!   [`overload_stress`]: the same deployment driven at 2x its
//!   oracle-predicted saturation rate, once with the SLO controller
//!   and once with the static plan.  Controller-on holds p99 within
//!   1.5x of target with a bounded shed fraction; the static plan's
//!   p99 diverges past 5x target.  Both rows are asserted here (the
//!   bench FAILS if the loop regresses) and schema-gated in CI via
//!   `--check`.

use std::path::Path;
use std::time::Duration;

use ffcnn::config::SloPolicy;
use ffcnn::coordinator::sim::{overload_stress, OverloadOutcome, OVERLOAD_N};
use ffcnn::coordinator::{ControlPlane, KnobValues, SloController};
use ffcnn::util::bench::Bench;
use ffcnn::util::sim::Clock;
use ffcnn::util::Json;

/// One overload-stress world: fresh seeded sim clock, registered
/// driver, the shared experiment, clean teardown.
fn stress(seed: u64, slo_on: bool) -> OverloadOutcome {
    let clock = Clock::sim(seed);
    let sched = clock.sched().expect("sim clock has a scheduler").clone();
    let reg = clock.register("driver");
    reg.start();
    let out = overload_stress(&clock, slo_on).expect("overload stress");
    let _ = sched.take_log();
    assert!(!sched.is_poisoned(), "sim scheduler poisoned after stress");
    out
}

fn base_knobs() -> KnobValues {
    KnobValues {
        max_batch: 4,
        max_wait_nanos: 1_000_000,
        max_shards: 1,
        max_queue: 64,
    }
}

fn main() {
    // `--check` dry-run: validate the previously written artifact's
    // schema and exit (the CI drift gate).
    if ffcnn::util::bench::check_mode(Path::new("BENCH_control.json")) {
        return;
    }
    let mut b = Bench::new("control").with_budget(Duration::from_secs(2));
    let mut extra: Vec<(String, Json)> = Vec::new();

    // Controller overhead: 64 ticks over an oscillating load, so the
    // law walks tighten AND relax (plane construction included; it is
    // a one-time boot cost).
    b.run("controller_64_ticks", || {
        let plane = ControlPlane::new(
            SloPolicy::target_ms(10, 64),
            base_knobs(),
            2,
            vec![1.0, 2.0, 4.0, 8.0],
        );
        let mut ctl = SloController::new(plane.clone());
        for i in 0..64u64 {
            let ms = if i % 2 == 0 { 50.0 } else { 1.0 };
            for _ in 0..32 {
                plane.hist.record_ms(ms);
            }
            ctl.tick(4);
        }
        plane.events().len()
    });

    // Admission on the submit hot path: alternate admitted and shed
    // so both branches are priced.
    {
        let plane = ControlPlane::new(
            SloPolicy::target_ms(10, 1_000_000),
            KnobValues { max_queue: 1_000_000, ..base_knobs() },
            2,
            Vec::new(),
        );
        b.run("admit_mixed_1k", || {
            let mut admitted = 0usize;
            for i in 0..1000usize {
                let queued = if i % 2 == 0 { 0 } else { 2_000_000 };
                if plane.admit(1, queued, i as u64 * 1_000).is_ok() {
                    admitted += 1;
                }
            }
            admitted
        });
    }

    // The headline: 2x saturation, controller on vs static plan, in
    // virtual time.  Same world seed for both so the arrival schedule
    // is identical.
    let on = stress(1, true);
    let off = stress(1, false);
    println!(
        "overload @2x saturation ({:.0} rps offered, target {:.1} ms):",
        on.offered_rps, on.target_ms
    );
    println!(
        "  controller-on : p99 {:.3} ms, served {}, shed {} ({:.0}%)",
        on.p99_ms,
        on.served,
        on.shed,
        on.shed_fraction * 100.0
    );
    println!(
        "  static plan   : p99 {:.3} ms, served {}, shed {}",
        off.p99_ms, off.served, off.shed
    );

    // The acceptance gates — a regression here FAILS the bench run.
    assert_eq!(on.other_errors, 0, "controller-on run had untyped errors");
    assert_eq!(off.other_errors, 0, "static run had untyped errors");
    assert!(
        on.p99_ms <= 1.5 * on.target_ms,
        "controller-on p99 {:.3} ms blew 1.5x target {:.3} ms",
        on.p99_ms,
        on.target_ms
    );
    assert!(
        off.p99_ms > 5.0 * on.target_ms,
        "static p99 {:.3} ms did not diverge past 5x target {:.3} ms \
         (overload too gentle to mean anything)",
        off.p99_ms,
        on.target_ms
    );
    assert!(on.shed > 0, "controller-on run shed nothing at 2x saturation");
    assert!(
        on.shed_fraction <= 0.75,
        "shed fraction {:.2} unbounded",
        on.shed_fraction
    );
    assert_eq!(off.shed, 0, "static plan has no admission control");
    assert!(!on.events.is_empty(), "control plane logged no events");
    // Deterministic replay: same seed, byte-identical control log.
    let on2 = stress(1, true);
    assert_eq!(on.events, on2.events, "control event log not reproducible");

    extra.push(("overload_n".into(), Json::num(OVERLOAD_N as f64)));
    extra.push(("p99_target_ms".into(), Json::num(on.target_ms)));
    extra.push(("saturation_rps".into(), Json::num(on.saturation_rps)));
    extra.push(("offered_rps".into(), Json::num(on.offered_rps)));
    extra.push(("controller_on_p99_ms".into(), Json::num(on.p99_ms)));
    extra.push((
        "controller_on_shed_fraction".into(),
        Json::num(on.shed_fraction),
    ));
    extra.push((
        "controller_on_served".into(),
        Json::num(on.served as f64),
    ));
    extra.push(("static_p99_ms".into(), Json::num(off.p99_ms)));
    extra.push(("static_served".into(), Json::num(off.served as f64)));
    extra.push((
        "static_over_target".into(),
        Json::num(off.p99_ms / on.target_ms),
    ));
    extra.push((
        "control_events".into(),
        Json::num(on.events.len() as f64),
    ));

    b.save_json(
        Path::new("BENCH_control.json"),
        extra.iter().map(|(k, v)| (k.as_str(), v.clone())).collect(),
    )
    .expect("writing BENCH_control.json");
    b.finish();
}
