//! Bench T1: regenerate every Table 1 row and time the cost models.
//!
//! The *output* (printed rows) is the experiment; the timings show the
//! models are cheap enough to sit inside the DSE inner loop.

use std::time::Duration;

use ffcnn::models;
use ffcnn::report::{render_table1, table1_rows};
use ffcnn::util::bench::Bench;

fn main() {
    let model = models::alexnet();

    // The experiment itself: print the reproduced table once.
    println!("{}", render_table1(&table1_rows(&model)));

    let mut b = Bench::new("table1").with_budget(Duration::from_secs(3));
    b.run("all_rows_alexnet", || table1_rows(&model));
    b.run("render", || {
        let rows = table1_rows(&model);
        render_table1(&rows).len()
    });
    let resnet = models::resnet50();
    b.run("all_rows_resnet50", || table1_rows(&resnet));
    b.finish();
}
