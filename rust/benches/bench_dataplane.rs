//! Bench: the data plane itself — the `util::vecops` wide kernels
//! against per-element scalar baselines, plus a 1 → N submitter-thread
//! scaling row over the striped-lane coordinator.  Pinned into
//! `BENCH_dataplane.json`.
//!
//! Two claims are asserted, not just recorded:
//!
//! - the wide gather and byte→f32 convert kernels move bytes at least
//!   `KERNEL_SPEEDUP_FLOOR`× faster than the per-element scalar code
//!   shape they replaced;
//! - N submitter threads over N striped lanes retain at least
//!   `SCALING_EFFICIENCY_FLOOR` of linear throughput scaling
//!   (asserted only when the machine actually has ≥ 2 cores — set
//!   `FFCNN_BENCH_CORES=1` to degrade gracefully on single-core CI).
//!
//! The scalar baselines pin every element through
//! `std::hint::black_box`: without it LLVM auto-vectorizes the naive
//! loop and the row measures the *same* SIMD code as the wide kernel.
//! The pessimized loop is the honest stand-in for the pre-PR
//! one-element-at-a-time copy shape.  Both shapes are checked
//! bit-equal before timing — the speedup never buys a numerics drift.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use ffcnn::config::{ServingConfig, SloPolicy};
use ffcnn::coordinator::{InferenceService, Pace, Policy};
use ffcnn::plan::Plan;
use ffcnn::util::bench::Bench;
use ffcnn::util::{vecops, Json};

/// Gather shape: one large reply slab (rows × tinynet logit rows are
/// too small to time; this is the shard-reassembly shape).
const ROWS: usize = 512;
/// tinynet image numel (3 × 16 × 16).
const ROW_LEN: usize = 768;
/// Bytes fed to the byte→f32 convert rows (1 MiB, a weight-blob chunk).
const CONVERT_FLOATS: usize = 256 * 1024;
/// Elements per quantize row.
const QUANT_N: usize = 256 * 1024;
/// Requests per `submit_many` group in the scaling rows.
const GROUP: usize = 128;
/// Groups pumped per thread per iteration.
const GROUPS: usize = 4;
/// Wide kernels must beat the scalar code shape by at least this.
const KERNEL_SPEEDUP_FLOOR: f64 = 1.5;
/// N threads must retain at least this fraction of linear scaling.
const SCALING_EFFICIENCY_FLOOR: f64 = 0.35;

/// Submitter-thread count: `FFCNN_BENCH_CORES` wins (CI runners lie
/// about their usable parallelism), else the detected core count,
/// capped at 8 like the service's parallel gather.
fn bench_threads() -> usize {
    std::env::var("FFCNN_BENCH_CORES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .min(8)
}

/// One closed-loop pump: `groups` bulk groups of `GROUP` requests.
fn pump(svc: &InferenceService, image: &Arc<[f32]>, groups: usize) -> usize {
    let mut served = 0usize;
    for _ in 0..groups {
        let set = svc
            .submit_many(std::iter::repeat_with(|| image.clone()).take(GROUP))
            .unwrap();
        set.wait_each(|r| {
            r.unwrap();
            served += 1;
        });
    }
    served
}

fn main() {
    // `--check` dry-run: validate the previously written artifact's
    // schema and exit (the CI drift gate).
    if ffcnn::util::bench::check_mode(Path::new("BENCH_dataplane.json")) {
        return;
    }
    let mut b = Bench::new("dataplane").with_budget(Duration::from_secs(2));
    // bytes / ns == GB/s exactly (both are 1e9-based).
    let gbps = |bytes: usize, ns: u128| bytes as f64 / ns as f64;

    // ---- gather: rows → one flat slab --------------------------------
    let rows: Vec<Vec<f32>> = (0..ROWS)
        .map(|i| ffcnn::data::synth_images(1, (3, 16, 16), 100 + i as u64))
        .collect();
    let total = ROWS * ROW_LEN;
    let mut dst = vec![0.0f32; total];
    let mut dst_scalar = vec![0.0f32; total];
    vecops::gather_rows(&mut dst, rows.iter().map(|r| r.as_slice()));
    vecops::gather_rows_scalar(
        &mut dst_scalar,
        rows.iter().map(|r| r.as_slice()),
    );
    assert_eq!(dst, dst_scalar, "wide gather must stay bit-equal");

    let gather_wide_ns = b
        .run(&format!("gather_rows_wide_{total}"), || {
            vecops::gather_rows(&mut dst, rows.iter().map(|r| r.as_slice()));
            dst[total - 1]
        })
        .median_ns;
    let gather_scalar_ns = b
        .run(&format!("gather_rows_scalar_{total}"), || {
            let mut off = 0usize;
            for row in &rows {
                for &x in row {
                    dst[off] = std::hint::black_box(x);
                    off += 1;
                }
            }
            dst[total - 1]
        })
        .median_ns;

    // ---- convert: little-endian bytes → f32 --------------------------
    let bytes: Vec<u8> = (0..CONVERT_FLOATS)
        .flat_map(|i| (i as f32 * 0.25 - 1000.0).to_le_bytes())
        .collect();
    assert_eq!(
        vecops::bytes_to_f32_wide(&bytes),
        vecops::bytes_to_f32_scalar(&bytes),
        "wide convert must stay bit-equal"
    );
    let convert_wide_ns = b
        .run(&format!("bytes_to_f32_wide_{}", bytes.len()), || {
            vecops::bytes_to_f32_wide(&bytes).len()
        })
        .median_ns;
    let convert_scalar_ns = b
        .run(&format!("bytes_to_f32_scalar_{}", bytes.len()), || {
            let mut out = Vec::with_capacity(bytes.len() / 4);
            for c in bytes.chunks_exact(4) {
                out.push(std::hint::black_box(f32::from_le_bytes([
                    c[0], c[1], c[2], c[3],
                ])));
            }
            out.len()
        })
        .median_ns;

    // ---- quantize paths (recorded, not floor-asserted: the fp16
    // convert is compute-bound, not a memcpy shape) ---------------------
    let q_src: Vec<f32> = ffcnn::data::synth_images(1, (1, 512, 512), 9);
    assert_eq!(q_src.len(), QUANT_N);
    let mut q16 = vec![0u16; QUANT_N];
    let mut q8 = vec![0i8; QUANT_N];
    let mut deq = vec![0.0f32; QUANT_N];
    let scale = vecops::i8_scale(1.0);
    let f16_ns = b
        .run(&format!("f16_quant_dequant_{QUANT_N}"), || {
            vecops::quantize_f16(&q_src, &mut q16);
            vecops::dequantize_f16(&q16, &mut deq);
            deq[QUANT_N - 1]
        })
        .median_ns;
    let i8_ns = b
        .run(&format!("i8_quant_dequant_{QUANT_N}"), || {
            vecops::quantize_i8(&q_src, &mut q8, scale);
            vecops::dequantize_i8(&q8, &mut deq, scale);
            deq[QUANT_N - 1]
        })
        .median_ns;

    // ---- service scaling: 1 → N submitter threads --------------------
    let threads = bench_threads();
    let plan = Plan::builder()
        .model("tinynet")
        .pace(Pace::Immediate)
        .policy(Policy::LeastOutstanding)
        .serving(ServingConfig {
            boards: threads,
            max_batch: 8,
            max_wait_ms: 0,
            // Host-latency feedback on, so the scaling rows and the
            // SLO controller see the same measured numbers.  The
            // bounds are far above anything this bench reaches — the
            // loop observes, it never sheds or tightens here.
            slo: Some(
                SloPolicy::target_ms(60_000, 1 << 20).with_host_feedback(),
            ),
            ..Default::default()
        })
        .build()
        .unwrap();
    let svc = plan.deploy().unwrap().serve().unwrap();
    let image: Arc<[f32]> = ffcnn::data::synth_images(1, (3, 16, 16), 7).into();
    for _ in 0..4 {
        pump(&svc, &image, 1);
    }

    let one_ns = b
        .run(&format!("service_scale_1t_{}", GROUP * GROUPS), || {
            pump(&svc, &image, GROUPS)
        })
        .median_ns;
    let n_ns = if threads >= 2 {
        b.run(&format!("service_scale_{threads}t_{}", GROUP * GROUPS), || {
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..threads)
                    .map(|_| {
                        let image = image.clone();
                        let svc = &svc;
                        s.spawn(move || pump(svc, &image, GROUPS))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
            })
        })
        .median_ns
    } else {
        one_ns
    };
    let host_ewma_ms =
        svc.control().map(|p| p.host_ms_per_item()).unwrap_or(0.0);

    // ---- derived metrics + floors ------------------------------------
    let fbytes = total * 4;
    let gather_gbps = gbps(fbytes, gather_wide_ns);
    let gather_speedup = gather_scalar_ns as f64 / gather_wide_ns as f64;
    let convert_gbps = gbps(bytes.len(), convert_wide_ns);
    let convert_speedup = convert_scalar_ns as f64 / convert_wide_ns as f64;
    let rps = |n: usize, ns: u128| n as f64 / (ns as f64 / 1e9);
    let rps_1t = rps(GROUP * GROUPS, one_ns);
    let rps_nt = rps(threads * GROUP * GROUPS, n_ns);
    let efficiency = if threads >= 2 {
        rps_nt / (rps_1t * threads as f64)
    } else {
        1.0
    };

    println!(
        "gather:  {gather_gbps:.2} GB/s, {gather_speedup:.2}x vs scalar\n\
         convert: {convert_gbps:.2} GB/s, {convert_speedup:.2}x vs scalar\n\
         f16 quant+dequant: {:.2} GB/s | i8 quant+dequant: {:.2} GB/s\n\
         service: {rps_1t:.0} req/s @1t, {rps_nt:.0} req/s @{threads}t \
         (efficiency {efficiency:.2}) | host EWMA {host_ewma_ms:.4} ms/item",
        gbps(QUANT_N * 4, f16_ns),
        gbps(QUANT_N * 4, i8_ns),
    );

    assert!(
        gather_speedup >= KERNEL_SPEEDUP_FLOOR,
        "wide gather regressed to {gather_speedup:.2}x vs scalar \
         (floor {KERNEL_SPEEDUP_FLOOR}x)"
    );
    assert!(
        convert_speedup >= KERNEL_SPEEDUP_FLOOR,
        "wide byte→f32 convert regressed to {convert_speedup:.2}x vs \
         scalar (floor {KERNEL_SPEEDUP_FLOOR}x)"
    );
    if threads >= 2 {
        assert!(
            efficiency >= SCALING_EFFICIENCY_FLOOR,
            "striped-lane scaling collapsed: {efficiency:.2} efficiency \
             at {threads} threads (floor {SCALING_EFFICIENCY_FLOOR})"
        );
    }

    b.save_json(
        Path::new("BENCH_dataplane.json"),
        vec![
            ("gather_gbps", Json::num(gather_gbps)),
            ("gather_speedup_vs_scalar", Json::num(gather_speedup)),
            ("convert_gbps", Json::num(convert_gbps)),
            ("convert_speedup_vs_scalar", Json::num(convert_speedup)),
            ("f16_quant_dequant_gbps", Json::num(gbps(QUANT_N * 4, f16_ns))),
            ("i8_quant_dequant_gbps", Json::num(gbps(QUANT_N * 4, i8_ns))),
            ("service_scale_threads", Json::num(threads as f64)),
            ("requests_per_sec_1t", Json::num(rps_1t)),
            ("requests_per_sec_nt", Json::num(rps_nt)),
            ("scaling_efficiency", Json::num(efficiency)),
            ("host_ewma_ms_per_item", Json::num(host_ewma_ms)),
        ],
    )
    .expect("writing BENCH_dataplane.json");
    b.finish();
}
