//! Bench: the coordinator itself — raw submit→route→batch→gather
//! speed at `Pace::Immediate` (engine-less boards, no artifacts
//! needed), pinned into `BENCH_service.json`.
//!
//! Three closed-loop shapes on the same service:
//!
//! - `per_request_serial`    — submit + wait one at a time: the
//!   pre-PR client pattern (each request pays a full per-request
//!   lock/wake round trip).  This is the "old-style path" baseline
//!   the speedup extra is computed against.
//! - `per_request_pipelined` — per-request `submit` with all replies
//!   collected afterwards: per-request enqueue cost, overlapped.
//! - `bulk_submit_many`      — [`submit_many`] groups: ONE id
//!   reservation, ONE counter update, ONE pool lock and ONE consumer
//!   wake per group.  `requests_per_sec`, `p50_ms`/`p99_ms` and
//!   `allocs_per_request` are measured here.
//!
//! [`submit_many`]: ffcnn::coordinator::InferenceService::submit_many

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use ffcnn::config::ServingConfig;
use ffcnn::coordinator::{LatencyHistogram, Pace, Policy};
use ffcnn::plan::Plan;
use ffcnn::util::alloc::{allocation_count, CountingAlloc};
use ffcnn::util::bench::Bench;
use ffcnn::util::Json;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Requests per iteration of the per-request rows.
const SERIAL: usize = 256;
/// Requests per `submit_many` group.
const GROUP: usize = 256;
/// Groups per iteration of the bulk row.
const GROUPS: usize = 16;

fn main() {
    // `--check` dry-run: validate the previously written artifact's
    // schema and exit (the CI drift gate).
    if ffcnn::util::bench::check_mode(Path::new("BENCH_service.json")) {
        return;
    }
    let plan = Plan::builder()
        .model("tinynet")
        .pace(Pace::Immediate)
        .policy(Policy::LeastOutstanding)
        .serving(ServingConfig {
            boards: 1,
            max_batch: 8,
            max_wait_ms: 0,
            ..Default::default()
        })
        .build()
        .unwrap();
    let svc = plan.deploy().unwrap().serve().unwrap();
    // One shared image: the submit path is zero-copy, so the bench
    // measures the coordinator, not memcpy.
    let image: Arc<[f32]> =
        ffcnn::data::synth_images(1, (3, 16, 16), 7).into();

    // Warm every pool to steady state: reply-slot freelist, scratch
    // bundle, reply slabs, batcher staging, the board's cost-oracle
    // memo and its reply slab.
    for _ in 0..4 {
        let set = svc
            .submit_many(
                std::iter::repeat_with(|| image.clone()).take(GROUP),
            )
            .unwrap();
        set.wait_each(|r| {
            r.unwrap();
        });
        let _ = svc.classify(image.clone()).unwrap();
    }

    let mut b = Bench::new("service").with_budget(Duration::from_secs(3));

    let serial_ns = b
        .run(&format!("per_request_serial_{SERIAL}"), || {
            let mut last = 0usize;
            for _ in 0..SERIAL {
                last = svc.classify(image.clone()).unwrap().argmax;
            }
            last
        })
        .median_ns;

    let pipelined_ns = b
        .run(&format!("per_request_pipelined_{SERIAL}"), || {
            let mut pend = Vec::with_capacity(SERIAL);
            for _ in 0..SERIAL {
                pend.push(svc.submit(image.clone()).unwrap());
            }
            let mut last = 0usize;
            for p in pend {
                last = p.wait().unwrap().argmax;
            }
            last
        })
        .median_ns;

    let hist = LatencyHistogram::new();
    let bulk_ns = b
        .run(&format!("bulk_submit_many_{}", GROUP * GROUPS), || {
            let mut served = 0usize;
            for _ in 0..GROUPS {
                let set = svc
                    .submit_many(
                        std::iter::repeat_with(|| image.clone())
                            .take(GROUP),
                    )
                    .unwrap();
                set.wait_each(|r| {
                    hist.record_ms(r.unwrap().latency_ms);
                    served += 1;
                });
            }
            served
        })
        .median_ns;

    // Steady-state allocation audit: one warm bulk group, counted by
    // the process-wide counting allocator.  (The hard `== 0` assertion
    // lives in tests/service_hammer.rs on a deterministic window; the
    // bench records what a full concurrent group observes.)
    let a0 = allocation_count();
    let set = svc
        .submit_many(std::iter::repeat_with(|| image.clone()).take(GROUP))
        .unwrap();
    set.wait_each(|r| {
        r.unwrap();
    });
    let allocs_per_request =
        (allocation_count() - a0) as f64 / GROUP as f64;

    let rps = |total: usize, ns: u128| total as f64 / (ns as f64 / 1e9);
    let serial_rps = rps(SERIAL, serial_ns);
    let pipelined_rps = rps(SERIAL, pipelined_ns);
    let bulk_rps = rps(GROUP * GROUPS, bulk_ns);
    let summary = hist.summary();
    println!(
        "pre-PR style (serial per-request): {serial_rps:.0} req/s\n\
         per-request pipelined:             {pipelined_rps:.0} req/s\n\
         bulk submit_many:                  {bulk_rps:.0} req/s \
         ({:.1}x vs pre-PR style)\n\
         host latency: p50 {:.3} ms, p99 {:.3} ms | \
         allocations/request: {allocs_per_request:.3}",
        bulk_rps / serial_rps,
        summary.p50_ms,
        summary.p99_ms
    );

    b.save_json(
        Path::new("BENCH_service.json"),
        vec![
            ("requests_per_sec", Json::num(bulk_rps)),
            ("requests_per_sec_pre_pr_style", Json::num(serial_rps)),
            ("requests_per_sec_pipelined", Json::num(pipelined_rps)),
            (
                "speedup_vs_pre_pr_style",
                Json::num(bulk_rps / serial_rps),
            ),
            ("p50_ms", Json::num(summary.p50_ms)),
            ("p99_ms", Json::num(summary.p99_ms)),
            ("allocs_per_request", Json::num(allocs_per_request)),
        ],
    )
    .expect("writing BENCH_service.json");
    b.finish();
}
