//! Bench E4: coordinator throughput — batcher planning, router picks,
//! and end-to-end service throughput on tinynet (fast) with batching
//! on and off.

use std::time::Duration;

use ffcnn::config::{default_artifacts_dir, ServingConfig};
use ffcnn::coordinator::{plan_chunks, Pace, Policy, Router};
use ffcnn::data;
use ffcnn::plan::Plan;
use ffcnn::util::bench::Bench;

fn main() {
    let mut b = Bench::new("coordinator").with_budget(Duration::from_secs(4));

    // Pure host-side logic (no engine).
    b.run("plan_chunks_1000", || {
        (0..1000usize).map(|n| plan_chunks(n % 37, &[1, 2, 4, 8]).len()).sum::<usize>()
    });
    {
        let (t1, _r1) = std::sync::mpsc::sync_channel(1024);
        let (t2, _r2) = std::sync::mpsc::sync_channel(1024);
        let router = Router::new(vec![t1, t2], Policy::LeastOutstanding);
        b.run("router_pick_10k", || {
            (0..10_000).map(|_| router.pick()).sum::<usize>()
        });
    }

    // End-to-end service (needs artifacts).
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("no artifacts; skipping service benches");
        b.finish();
        return;
    }
    let plan = Plan::builder()
        .model("tinynet")
        .conv_impl("pallas")
        .artifacts_dir(dir)
        .pace(Pace::None)
        .policy(Policy::LeastOutstanding)
        .serving(ServingConfig {
            max_batch: 2,
            max_wait_ms: 1,
            ..Default::default()
        })
        .build()
        .unwrap();
    let svc = plan.deploy().unwrap().serve().unwrap();
    let img = data::synth_images(1, (3, 16, 16), 9);
    // warm
    let _ = svc.classify(img.clone()).unwrap();

    b.run("classify_single", || {
        svc.classify(img.clone()).unwrap().argmax
    });
    b.run("burst_16_batched", || {
        let trace = data::burst_trace(16);
        let r = svc.run_trace(
            &trace,
            |id| data::synth_images(1, (3, 16, 16), id),
            0.0,
        );
        assert_eq!(r.errors, 0);
        (r.throughput_rps * 1000.0) as u64
    });
    b.finish();
}
