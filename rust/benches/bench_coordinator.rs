//! Bench E4: coordinator throughput — batcher planning, router picks,
//! end-to-end service throughput on tinynet, and multi-board batch
//! sharding: sharded vs unsharded batch latency at batch 16/32/64 on
//! the 4-board config, both *predicted* (the shard-aware simulator,
//! no artifacts needed) and *measured* through the serving stack when
//! artifacts exist.  Results land in `BENCH_coordinator.json`
//! (uploaded as a CI artifact next to `BENCH_dse.json` /
//! `BENCH_pipeline.json`).

use std::path::Path;
use std::time::Duration;

use ffcnn::config::{default_artifacts_dir, ServingConfig, ShardPolicy};
use ffcnn::coordinator::{plan_chunks, Pace, Policy, Router, StealPool};
use ffcnn::data;
use ffcnn::fpga::device::STRATIX10;
use ffcnn::fpga::pipeline::Simulator;
use ffcnn::fpga::timing::ffcnn_stratix10_params;
use ffcnn::models;
use ffcnn::plan::Plan;
use ffcnn::util::bench::Bench;
use ffcnn::util::Json;

/// The multi-board configuration the shard rows compare on.
const SHARD_BOARDS: usize = 4;
const SHARD_BATCHES: [usize; 3] = [16, 32, 64];

fn main() {
    // `--check` dry-run: validate the previously written artifact's
    // schema and exit (the CI drift gate).
    if ffcnn::util::bench::check_mode(Path::new("BENCH_coordinator.json")) {
        return;
    }
    let mut b = Bench::new("coordinator").with_budget(Duration::from_secs(4));
    let mut extra: Vec<(String, Json)> = Vec::new();

    // Pure host-side logic (no engine).
    b.run("plan_chunks_1000", || {
        (0..1000usize).map(|n| plan_chunks(n % 37, &[1, 2, 4, 8]).len()).sum::<usize>()
    });
    {
        let pool = StealPool::new_pinned(2, 1024);
        let router = Router::new(pool, Policy::LeastOutstanding);
        b.run("router_pick_10k", || {
            (0..10_000).map(|_| router.pick()).sum::<usize>()
        });
    }

    // Predicted sharded vs unsharded batch latency (alexnet on the
    // paper's Stratix 10 point): the shard-aware simulator runs the
    // slowest ceil(B/k)-image shard plus per-shard dispatch overhead.
    // These rows are the acceptance numbers — sharded batch-64 must
    // sit strictly below unsharded on the 4-board config.
    let m = models::alexnet();
    let p = ffcnn_stratix10_params();
    for &batch in &SHARD_BATCHES {
        let unsharded =
            Simulator::new(&m, &STRATIX10, p).run(batch).time_ms();
        let sharded = Simulator::new(&m, &STRATIX10, p)
            .shards(SHARD_BOARDS)
            .run(batch)
            .time_ms();
        println!(
            "sim alexnet b{batch}: unsharded {unsharded:.2} ms, \
             sharded x{SHARD_BOARDS} {sharded:.2} ms ({:.2}x)",
            unsharded / sharded
        );
        extra.push((
            format!("sim_unsharded_b{batch}_ms"),
            Json::num(unsharded),
        ));
        extra.push((format!("sim_sharded_b{batch}_ms"), Json::num(sharded)));
        extra.push((
            format!("sim_shard_speedup_b{batch}"),
            Json::num(unsharded / sharded),
        ));
    }

    // ROADMAP item 5, bench half: the serving-visible effect of the
    // on-chip weight cache — the identical design point with the
    // prefetch window sized to the paper's 4 MiB vs disabled.  The
    // batch-1 row is the paper's exposed FC memory bound, where the
    // win is largest; larger batches amortize the stream and the
    // cache must still never hurt.
    for &batch in &[1usize, 16] {
        let cache_on = Simulator::new(&m, &STRATIX10, p)
            .weight_cache_kib(4096)
            .run(batch)
            .time_ms();
        let cache_off = Simulator::new(&m, &STRATIX10, p)
            .weight_cache_kib(0)
            .run(batch)
            .time_ms();
        assert!(
            cache_on <= cache_off,
            "weight cache slowed serving at b{batch}: \
             {cache_on:.3} ms > {cache_off:.3} ms"
        );
        println!(
            "sim alexnet b{batch}: cache-on {cache_on:.2} ms, \
             cache-off {cache_off:.2} ms ({:.3}x)",
            cache_off / cache_on
        );
        extra.push((format!("sim_cache_on_b{batch}_ms"), Json::num(cache_on)));
        extra
            .push((format!("sim_cache_off_b{batch}_ms"), Json::num(cache_off)));
        extra.push((
            format!("sim_cache_speedup_b{batch}"),
            Json::num(cache_off / cache_on),
        ));
    }

    // End-to-end service (needs artifacts).
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("no artifacts; skipping measured service benches");
        save(&b, &extra);
        b.finish();
        return;
    }
    let plan = Plan::builder()
        .model("tinynet")
        .conv_impl("pallas")
        .artifacts_dir(dir)
        .pace(Pace::None)
        .policy(Policy::LeastOutstanding)
        .serving(ServingConfig {
            max_batch: 2,
            max_wait_ms: 1,
            ..Default::default()
        })
        .build()
        .unwrap();
    let svc = plan.deploy().unwrap().serve().unwrap();
    let img = data::synth_images(1, (3, 16, 16), 9);
    // warm
    let _ = svc.classify(img.clone()).unwrap();

    b.run("classify_single", || {
        svc.classify(img.clone()).unwrap().argmax
    });
    b.run("burst_16_batched", || {
        let trace = data::burst_trace(16);
        let r = svc.run_trace(
            &trace,
            |t| data::synth_images(1, (3, 16, 16), t.id),
            0.0,
        );
        assert_eq!(r.errors, 0);
        (r.throughput_rps * 1000.0) as u64
    });
    drop(svc);

    // Measured sharded vs unsharded batch latency on SHARD_BOARDS
    // FPGA-paced boards (the regime the win lives in: boards are held
    // for the simulated batch time, so concurrency across boards is
    // what the wall clock sees).
    let mut whole = plan.clone();
    whole.pace = Pace::Fpga;
    whole.serving.boards = SHARD_BOARDS;
    let mut split = whole.clone();
    split.serving.shard = ShardPolicy::SplitOver(SHARD_BOARDS);
    let svc_whole = whole.deploy().unwrap().serve().unwrap();
    let svc_split = split.deploy().unwrap().serve().unwrap();
    let _ = svc_whole.classify(data::synth_images(1, (3, 16, 16), 1));
    let _ = svc_split.classify(data::synth_images(1, (3, 16, 16), 1));
    for &batch in &SHARD_BATCHES {
        let flat = data::synth_images(batch, (3, 16, 16), 77);
        let unsharded = b
            .run(&format!("serve_unsharded_b{batch}"), || {
                svc_whole
                    .classify_batch(flat.clone())
                    .unwrap()
                    .latency_ms as u64
            })
            .median_ms();
        let sharded = b
            .run(&format!("serve_sharded_b{batch}_x{SHARD_BOARDS}"), || {
                svc_split
                    .classify_batch(flat.clone())
                    .unwrap()
                    .latency_ms as u64
            })
            .median_ms();
        extra.push((
            format!("serve_unsharded_b{batch}_ms"),
            Json::num(unsharded),
        ));
        extra
            .push((format!("serve_sharded_b{batch}_ms"), Json::num(sharded)));
    }

    drop(svc_whole);
    drop(svc_split);

    // Measured cache axis through the serving stack: two FPGA-paced
    // boards that differ only in `design.weight_cache_kib`, pinning
    // that the knob reaches the paced execution path end to end.
    // (tinynet's cache win is small by construction; the predicted
    // alexnet rows above carry the headline.)
    let mut cache_on_plan = plan.clone();
    cache_on_plan.pace = Pace::Fpga;
    cache_on_plan.design.weight_cache_kib = 4096;
    let mut cache_off_plan = cache_on_plan.clone();
    cache_off_plan.design.weight_cache_kib = 0;
    let svc_con = cache_on_plan.deploy().unwrap().serve().unwrap();
    let svc_coff = cache_off_plan.deploy().unwrap().serve().unwrap();
    let on_ms = b
        .run("serve_cache_on_b1", || {
            svc_con.classify(img.clone()).unwrap().latency_ms as u64
        })
        .median_ms();
    let off_ms = b
        .run("serve_cache_off_b1", || {
            svc_coff.classify(img.clone()).unwrap().latency_ms as u64
        })
        .median_ms();
    extra.push(("serve_cache_on_b1_ms".to_string(), Json::num(on_ms)));
    extra.push(("serve_cache_off_b1_ms".to_string(), Json::num(off_ms)));

    save(&b, &extra);
    b.finish();
}

fn save(b: &Bench, extra: &[(String, Json)]) {
    b.save_json(
        Path::new("BENCH_coordinator.json"),
        extra.iter().map(|(k, v)| (k.as_str(), v.clone())).collect(),
    )
    .expect("writing BENCH_coordinator.json");
}
