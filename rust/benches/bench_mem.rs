//! Bench: the `fpga::mem` memory hierarchy — the weight-aware
//! prefetch window's cache-on vs cache-off latency on the token
//! simulator (batch 1 and 16, alexnet and vgg16), plus the fast-path
//! fidelity check with the cache enabled.
//!
//! Writes `BENCH_mem.json` (CI artifact next to `BENCH_pipeline.json`
//! / `BENCH_dse.json` / `BENCH_coordinator.json`).  The acceptance
//! rows: cache-on must strictly beat cache-off at batch 1 on vgg16
//! (the exposed FC weight streams the ROADMAP prefetch item targets),
//! never lose anywhere, and fast-vs-exact must stay ≤ 0.1% with the
//! cache on.
//!
//! `--check` dry-run: validate the previously written artifact's
//! schema and exit (the CI drift gate).

use std::path::Path;
use std::time::Duration;

use ffcnn::fpga::device::STRATIX10;
use ffcnn::fpga::pipeline::Simulator;
use ffcnn::fpga::timing::{ffcnn_stratix10_params, OverlapPolicy};
use ffcnn::models::{self, Model};
use ffcnn::util::bench::{check_mode, Bench};
use ffcnn::util::Json;

/// Cache size the headline rows compare at (the mid candidate of the
/// DSE axis; comfortably feasible on Stratix 10 M20K).
const CACHE_KIB: usize = 4096;

fn run(m: &Model, batch: usize, cache_kib: usize, exact: bool) -> u64 {
    Simulator::new(m, &STRATIX10, ffcnn_stratix10_params())
        .policy(OverlapPolicy::Full)
        .weight_cache_kib(cache_kib)
        .exact(exact)
        .run(batch)
        .total_cycles
}

fn ms(cycles: u64) -> f64 {
    cycles as f64 / (STRATIX10.fmax_mhz * 1e6) * 1e3
}

fn main() {
    let artifact = Path::new("BENCH_mem.json");
    if check_mode(artifact) {
        return;
    }

    let mut b = Bench::new("mem").with_budget(Duration::from_secs(4));
    let mut extra: Vec<(String, Json)> =
        vec![("weight_cache_kib".into(), Json::num(CACHE_KIB as f64))];

    println!(
        "weight-aware prefetch (token sim, Full overlap, stratix10, \
         {CACHE_KIB} KiB cache):"
    );
    let mut vgg_b1 = (0u64, 0u64);
    for (name, m) in
        [("alexnet", models::alexnet()), ("vgg16", models::vgg16())]
    {
        for batch in [1usize, 16] {
            let off = run(&m, batch, 0, false);
            let on = run(&m, batch, CACHE_KIB, false);
            println!(
                "  {name:<8} b{batch:<3} cache-off {off:>12} cy | \
                 cache-on {on:>12} cy | saves {:>7.3}%",
                (off as f64 - on as f64) / off as f64 * 100.0
            );
            // Whisker tolerance mirrors tests/mem.rs: a rate change
            // can flip a group between the exact loop and the closed
            // form, which agree only to f64 rounding — the headline
            // vgg16-b1 win below stays strict (its ~70k-cycle margin
            // dwarfs this whisker).
            assert!(
                on <= off + 8 + off / 100_000,
                "{name} b{batch}: cache-on {on} > cache-off {off}"
            );
            if (name, batch) == ("vgg16", 1) {
                vgg_b1 = (off, on);
            }
            extra.push((
                format!("{name}_b{batch}_cache_off_ms"),
                Json::num(ms(off)),
            ));
            extra.push((
                format!("{name}_b{batch}_cache_on_ms"),
                Json::num(ms(on)),
            ));
            extra.push((
                format!("{name}_b{batch}_cache_saving_pct"),
                Json::num((off as f64 - on as f64) / off as f64 * 100.0),
            ));
        }
    }
    // The acceptance row: batch 1 on vgg16 is where the FC weight
    // streams are exposed — the cache must win strictly there.
    assert!(
        vgg_b1.1 < vgg_b1.0,
        "cache-on must strictly beat cache-off on vgg16 b1: {} vs {}",
        vgg_b1.1,
        vgg_b1.0
    );

    // Fidelity with the cache on: the prefetch is a pure rate
    // adjustment, so the closed-form fast path must still track the
    // O(tokens) oracle within the pinned 0.1% budget.
    let alex = models::alexnet();
    let fast = run(&alex, 1, CACHE_KIB, false);
    let exact = run(&alex, 1, CACHE_KIB, true);
    let rel_err = fast.abs_diff(exact) as f64 / exact as f64;
    println!(
        "alexnet b1 cache-on: fast {fast} cy vs exact {exact} cy \
         (rel err {rel_err:.2e})"
    );
    assert!(
        rel_err <= 1e-3,
        "fast-vs-exact drifted past 0.1% with the cache on: {rel_err}"
    );
    extra.push(("mem_fast_vs_exact_rel_err".into(), Json::num(rel_err)));

    // Simulator cost: the cache must not change the solver's
    // complexity class (still O(depth + transient) per group).
    let vgg = models::vgg16();
    b.run("token_vgg16_b1_cache_off", || run(&vgg, 1, 0, false));
    b.run("token_vgg16_b1_cache_on", || {
        run(&vgg, 1, CACHE_KIB, false)
    });
    b.run("token_alexnet_b16_cache_on", || {
        run(&alex, 16, CACHE_KIB, false)
    });

    b.save_json(
        artifact,
        extra.iter().map(|(k, v)| (k.as_str(), v.clone())).collect(),
    )
    .expect("writing BENCH_mem.json");
    println!(
        "wrote BENCH_mem.json (vgg16 b1: cache-on {} < cache-off {})",
        vgg_b1.1, vgg_b1.0
    );
    b.finish();
}
