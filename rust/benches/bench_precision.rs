//! Bench E5: precision ablation — fp32 (the paper's choice) vs
//! fixed-16/fixed-8 variants of the same FFCNN design point, plus the
//! precision *axis* swept through the `Plan → Deployment` facade.
//!
//! Table 1's baselines differ on this axis (FPGA2016a is fixed 8-16b);
//! the ablation quantifies what FFCNN gives up for full precision: the
//! FC weight stream shrinks with element width and the MAC tree packs
//! more multipliers per DSP, so fixed point lifts both latency and
//! GOPS/DSP at batch 1.

use std::time::Duration;

use ffcnn::fpga::device::{ARRIA10, STRATIX10};
use ffcnn::fpga::dse::SweepSpace;
use ffcnn::fpga::resources::resource_usage;
use ffcnn::fpga::timing::{
    ffcnn_arria10_params, ffcnn_stratix10_params, simulate_model,
    OverlapPolicy, Precision,
};
use ffcnn::models;
use ffcnn::plan::Plan;
use ffcnn::util::bench::Bench;

fn main() {
    let model = models::alexnet();
    println!(
        "{:<12}{:<10}{:>10}{:>12}{:>10}{:>12}",
        "device", "precision", "DSPs", "time(ms)", "GOPS", "GOPS/DSP"
    );
    for (d, base) in [
        (&ARRIA10, ffcnn_arria10_params()),
        (&STRATIX10, ffcnn_stratix10_params()),
    ] {
        for (name, prec) in [
            ("fp32", Precision::Fp32),
            ("fixed16", Precision::Fixed16),
            ("fixed8", Precision::Fixed8),
        ] {
            let p = base.with_precision(prec);
            let u = resource_usage(&p, d);
            let t =
                simulate_model(&model, d, &p, 1, OverlapPolicy::WithinGroup);
            println!(
                "{:<12}{:<10}{:>10}{:>12.2}{:>10.1}{:>12.3}",
                d.name,
                name,
                u.dsps,
                t.time_per_image_ms(),
                t.gops(),
                t.gops() / u.dsps as f64
            );
        }
    }

    // The axis as a sweep dimension: one deployment.sweep() over the
    // whole (vec, lane) x precision grid picks the per-precision
    // optima that the fixed-point row above only samples at the FFCNN
    // point.
    let plan = Plan::builder()
        .model("alexnet")
        .device("stratix10")
        .sweep(SweepSpace::with_precision())
        .build()
        .unwrap();
    let dep = plan.deploy().unwrap();
    let sweep = dep.sweep();
    println!("\nprecision axis via deployment.sweep():");
    for (prec, p) in sweep.best_latency_per_precision() {
        println!(
            "  {:<10} best vec={:<3} lane={:<3} -> {:>8.2} ms",
            format!("{prec:?}"),
            p.params.vec_size,
            p.params.lane_num,
            p.time_ms
        );
    }

    let mut b = Bench::new("precision").with_budget(Duration::from_secs(2));
    let p8 = ffcnn_stratix10_params().with_precision(Precision::Fixed8);
    b.run("simulate_fixed8_alexnet", || {
        simulate_model(&model, &STRATIX10, &p8, 1, OverlapPolicy::WithinGroup)
            .total_cycles
    });
    b.run("sweep_precision_axis", || dep.sweep().points.len());
    b.finish();
}
