//! Bench E5: precision ablation — fp32 (the paper's choice) vs
//! fixed-16/fixed-8 variants of the same FFCNN design point.
//!
//! Table 1's baselines differ on this axis (FPGA2016a is fixed 8-16b);
//! the ablation quantifies what FFCNN gives up for full precision: the
//! FC weight stream shrinks with element width and the MAC tree packs
//! more multipliers per DSP, so fixed point lifts both latency and
//! GOPS/DSP at batch 1.

use std::time::Duration;

use ffcnn::fpga::device::{ARRIA10, STRATIX10};
use ffcnn::fpga::resources::resource_usage;
use ffcnn::fpga::timing::{
    ffcnn_arria10_params, ffcnn_stratix10_params, simulate_model,
    OverlapPolicy, Precision,
};
use ffcnn::models;
use ffcnn::util::bench::Bench;

fn main() {
    let model = models::alexnet();
    println!(
        "{:<12}{:<10}{:>10}{:>12}{:>10}{:>12}",
        "device", "precision", "DSPs", "time(ms)", "GOPS", "GOPS/DSP"
    );
    for (d, base) in [
        (&ARRIA10, ffcnn_arria10_params()),
        (&STRATIX10, ffcnn_stratix10_params()),
    ] {
        for (name, prec) in [
            ("fp32", Precision::Fp32),
            ("fixed16", Precision::Fixed16),
            ("fixed8", Precision::Fixed8),
        ] {
            let p = base.with_precision(prec);
            let u = resource_usage(&p, d);
            let t =
                simulate_model(&model, d, &p, 1, OverlapPolicy::WithinGroup);
            println!(
                "{:<12}{:<10}{:>10}{:>12.2}{:>10.1}{:>12.3}",
                d.name,
                name,
                u.dsps,
                t.time_per_image_ms(),
                t.gops(),
                t.gops() / u.dsps as f64
            );
        }
    }

    let mut b = Bench::new("precision").with_budget(Duration::from_secs(2));
    let p8 = ffcnn_stratix10_params().with_precision(Precision::Fixed8);
    b.run("simulate_fixed8_alexnet", || {
        simulate_model(&model, &STRATIX10, &p8, 1, OverlapPolicy::WithinGroup)
            .total_cycles
    });
    b.finish();
}
