//! Bench: the PJRT runtime hot path — input upload, execute, download —
//! for the AOT artifacts (perf-pass instrumentation lives here).

use std::time::Duration;

use ffcnn::config::default_artifacts_dir;
use ffcnn::data;
use ffcnn::models;
use ffcnn::runtime::Engine;
use ffcnn::util::bench::Bench;

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("no artifacts (run `make artifacts`); nothing to bench");
        return;
    }
    let engine = Engine::open(&dir).unwrap();
    let mut b = Bench::new("runtime").with_budget(Duration::from_secs(8));

    // Tiny model: measures framework overhead (upload+dispatch+download).
    engine.warm("tinynet_b1_pallas").unwrap();
    let tiny_in = data::synth_images(1, models::tinynet().in_shape, 1);
    b.run("tinynet_b1_pallas", || {
        engine.execute("tinynet_b1_pallas", &tiny_in).unwrap().len()
    });
    engine.warm("tinynet_b1_jnp").unwrap();
    b.run("tinynet_b1_jnp", || {
        engine.execute("tinynet_b1_jnp", &tiny_in).unwrap().len()
    });

    // AlexNet: the paper's benchmark network, batch scaling.
    let alex_shape = models::alexnet().in_shape;
    for batch in [1usize, 4, 8] {
        let name = format!("alexnet_b{batch}_jnp");
        if engine.warm(&name).is_err() {
            continue;
        }
        let input = data::synth_images(batch, alex_shape, 2);
        b.run(&name, || engine.execute(&name, &input).unwrap().len());
    }

    // alexnet_b1_pallas is deliberately NOT benched: the interpret-mode
    // grid loops make XLA-CPU compilation take tens of minutes (see
    // EXPERIMENTS.md §E1 notes).  Kernel correctness at full layer
    // geometry is covered by pytest; end-to-end pallas by tinynet.

    let s = engine.stats();
    println!(
        "cumulative: {} execs | upload {:.1} ms | execute {:.1} ms | \
         download {:.1} ms | compile {:.1} ms",
        s.executions,
        s.upload_us as f64 / 1e3,
        s.execute_us as f64 / 1e3,
        s.download_us as f64 / 1e3,
        s.compile_us as f64 / 1e3
    );
    b.finish();
}
