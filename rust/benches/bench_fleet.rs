//! Bench: heterogeneous-fleet serving with model affinity
//! (`coordinator::router::FleetState`).
//!
//! Two kinds of rows in `BENCH_fleet.json`:
//!
//! - **Measured** (`b.run`): the affinity-aware routing decision on
//!   the submit hot path — `pick_for` over a warm fleet, alternating
//!   models so both the warm-hit and the penalty branch are priced.
//! - **Headline** (extras): a 70/30 alexnet/vgg16 mix served
//!   closed-loop on a 2-device fleet, in *virtual* time
//!   (deterministic, engine-less, CI-fast), once with affinity
//!   routing and once without.  The two boards are same-speed
//!   (2x stratix10) on purpose: with equal compute everywhere, the
//!   ONLY difference between the runs is the swap churn, so
//!   "affinity never worse" is a property of the router, not of a
//!   lucky device assignment (the heterogeneous case is exercised by
//!   the `slow_member_death` scenario and `ffcnn dse --fleet-sweep`).
//!   Affinity keeps each model resident on its own board (zero
//!   swaps); the baseline ping-pongs models across boards and pays a
//!   weight-reload stall on every displacement.  The bench FAILS if
//!   affinity is ever worse on throughput or p99, and the artifact is
//!   schema-gated in CI via `--check`.

use std::path::Path;
use std::time::Duration;

use ffcnn::config::RunConfig;
use ffcnn::coordinator::{
    InferenceService, LatencyHistogram, Pace, Policy,
};
use ffcnn::fpga::timing::ffcnn_stratix10_params;
use ffcnn::plan::{FleetMember, FleetSpec, Plan};
use ffcnn::util::bench::Bench;
use ffcnn::util::sim::Clock;
use ffcnn::util::Json;
use ffcnn::Result;

/// Requests per mixed-serve run: enough waves for residency to
/// matter, short enough to keep the bench CI-fast.
const MIX_N: usize = 400;

/// Alexnet share of the request mix (vgg16 takes the rest).
const MIX: [f64; 2] = [0.7, 0.3];

/// Outcome of one closed-loop mixed-model run.
struct FleetOutcome {
    served: u64,
    req_per_s: f64,
    p99_ms: f64,
    swaps: u64,
    swap_stall_ms: f64,
}

/// The 2-device fleet under test: two stratix10 boards at the paper
/// design point serving alexnet + vgg16 (same-speed boards so the
/// affinity-on/off delta is pure swap cost — see the module doc).
fn mixed_plan(affinity: bool) -> Result<Plan> {
    let mut cfg = RunConfig::default();
    cfg.model = "alexnet".to_string();
    cfg.serving.max_batch = 4;
    cfg.serving.max_wait_ms = 1;
    cfg.serving.boards = 2;
    let mut plan =
        Plan::from_run_config(&cfg, Pace::Fpga, Policy::LeastOutstanding)?;
    plan.fleet = Some(FleetSpec {
        members: vec![FleetMember {
            device: "stratix10".to_string(),
            design: ffcnn_stratix10_params(),
            count: 2,
        }],
        models: vec!["alexnet".to_string(), "vgg16".to_string()],
        affinity,
    });
    Ok(plan)
}

/// Serve [`MIX_N`] requests closed-loop in waves of 4, picking models
/// by error diffusion over [`MIX`] (exact deterministic shares, no
/// RNG), and measure virtual-time throughput, p99, and swap cost.
fn run_mix(clock: &Clock, affinity: bool) -> Result<FleetOutcome> {
    let plan = mixed_plan(affinity)?;
    let svc = InferenceService::from_plan_with(&plan, clock.clone(), &[])?;
    let numels: Vec<usize> = (0..2)
        .map(|m| svc.model_dims(m).expect("served model has dims").0)
        .collect();
    let sched = clock.sched().expect("sim clock").clone();
    let hist = LatencyHistogram::new();
    let mut acc = [0.0f64; 2];
    let mut served = 0u64;
    let t0 = sched.now();
    let mut sent = 0usize;
    while sent < MIX_N {
        let wave = 4.min(MIX_N - sent);
        let mut pending = Vec::with_capacity(wave);
        for _ in 0..wave {
            for m in 0..2 {
                acc[m] += MIX[m];
            }
            let m = if acc[0] >= acc[1] { 0 } else { 1 };
            acc[m] -= 1.0;
            pending.push(svc.submit_model(m, vec![0.0f32; numels[m]])?);
        }
        sent += wave;
        for p in pending {
            let r = p.wait()?;
            hist.record_ms(r.latency_ms);
            served += 1;
        }
    }
    let elapsed_s = sched.now().saturating_sub(t0) as f64 / 1e9;
    let fleet = svc.fleet().expect("fleet service exposes FleetState");
    let out = FleetOutcome {
        served,
        req_per_s: served as f64 / elapsed_s.max(f64::MIN_POSITIVE),
        p99_ms: hist.quantile_ms(0.99),
        swaps: fleet.total_swaps(),
        swap_stall_ms: fleet.total_swap_nanos() as f64 / 1e6,
    };
    svc.stop();
    Ok(out)
}

/// One mixed-serve world: fresh seeded sim clock, registered driver,
/// the shared closed-loop experiment, clean teardown.
fn stress(seed: u64, affinity: bool) -> FleetOutcome {
    let clock = Clock::sim(seed);
    let sched = clock.sched().expect("sim clock has a scheduler").clone();
    let reg = clock.register("driver");
    reg.start();
    let out = run_mix(&clock, affinity).expect("fleet mix run");
    let _ = sched.take_log();
    assert!(!sched.is_poisoned(), "sim scheduler poisoned after run");
    out
}

fn main() {
    // `--check` dry-run: validate the previously written artifact's
    // schema and exit (the CI drift gate).
    if ffcnn::util::bench::check_mode(Path::new("BENCH_fleet.json")) {
        return;
    }
    let mut b = Bench::new("fleet").with_budget(Duration::from_secs(2));

    // Routing overhead: the affinity-aware pick on a warm 4-board
    // fleet, alternating models so warm hits AND penalized misses are
    // both on the measured path.
    {
        use ffcnn::coordinator::router::{FleetState, Router, StealPool};
        let pool = StealPool::new_pinned(4, 8);
        let fleet = FleetState::new(4, true);
        fleet.claim(0, 0);
        fleet.claim(1, 1);
        let router =
            Router::with_fleet(pool, Policy::LeastOutstanding, fleet);
        b.run("pick_for_warm_fleet_1k", || {
            let mut acc = 0usize;
            for i in 0..1000usize {
                acc += router.pick_for(i % 2);
            }
            acc
        });
    }

    // The headline: same seed (identical arrival order and mix) with
    // affinity routing on vs off.
    let on = stress(1, true);
    let off = stress(1, false);
    println!(
        "mixed serve ({} reqs, {:.0}/{:.0} alexnet/vgg16, \
         2x stratix10):",
        MIX_N,
        MIX[0] * 100.0,
        MIX[1] * 100.0
    );
    println!(
        "  affinity-on : {:.1} req/s, p99 {:.3} ms, {} swaps \
         ({:.3} ms stalled)",
        on.req_per_s, on.p99_ms, on.swaps, on.swap_stall_ms
    );
    println!(
        "  affinity-off: {:.1} req/s, p99 {:.3} ms, {} swaps \
         ({:.3} ms stalled)",
        off.req_per_s, off.p99_ms, off.swaps, off.swap_stall_ms
    );

    // The acceptance gates — a regression here FAILS the bench run.
    assert_eq!(on.served, MIX_N as u64, "affinity-on lost requests");
    assert_eq!(off.served, MIX_N as u64, "affinity-off lost requests");
    assert!(
        on.req_per_s >= off.req_per_s,
        "affinity routing lost throughput: {:.1} < {:.1} req/s",
        on.req_per_s,
        off.req_per_s
    );
    assert!(
        on.p99_ms <= off.p99_ms,
        "affinity routing lost p99: {:.3} > {:.3} ms",
        on.p99_ms,
        off.p99_ms
    );
    assert!(
        on.swaps < off.swaps,
        "affinity did not reduce swaps: {} vs {}",
        on.swaps,
        off.swaps
    );
    assert!(
        off.swap_stall_ms > 0.0,
        "baseline paid no swap cost — the mix never displaced anything"
    );

    let extra: Vec<(String, Json)> = vec![
        ("mix_n".into(), Json::num(MIX_N as f64)),
        ("mix_alexnet".into(), Json::num(MIX[0])),
        ("mix_vgg16".into(), Json::num(MIX[1])),
        ("affinity_on_req_per_s".into(), Json::num(on.req_per_s)),
        ("affinity_on_p99_ms".into(), Json::num(on.p99_ms)),
        ("affinity_on_swaps".into(), Json::num(on.swaps as f64)),
        (
            "affinity_on_swap_stall_ms".into(),
            Json::num(on.swap_stall_ms),
        ),
        ("affinity_off_req_per_s".into(), Json::num(off.req_per_s)),
        ("affinity_off_p99_ms".into(), Json::num(off.p99_ms)),
        ("affinity_off_swaps".into(), Json::num(off.swaps as f64)),
        (
            "affinity_off_swap_stall_ms".into(),
            Json::num(off.swap_stall_ms),
        ),
        (
            "speedup_req_per_s".into(),
            Json::num(on.req_per_s / off.req_per_s.max(f64::MIN_POSITIVE)),
        ),
    ];

    b.save_json(
        Path::new("BENCH_fleet.json"),
        extra.iter().map(|(k, v)| (k.as_str(), v.clone())).collect(),
    )
    .expect("writing BENCH_fleet.json");
    b.finish();
}
