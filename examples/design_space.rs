//! Design-space exploration (experiment E2) through the
//! `Plan → Deployment` facade: sweep the accelerator's
//! (VEC_SIZE, LANE_NUM) grid on both of the paper's devices, print
//! the Pareto frontier and the chosen design points, show how the
//! optimum shifts with batch size, and run the extended
//! precision × overlap × channel-depth sweep in one
//! `deployment.sweep()` call.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use ffcnn::fpga::dse::SweepSpace;
use ffcnn::fpga::Fidelity;
use ffcnn::plan::Plan;
use ffcnn::Result;

fn main() -> Result<()> {
    for device in ["arria10", "stratix10"] {
        // The device default design point IS the paper's point.
        let mut plan =
            Plan::builder().model("alexnet").device(device).build()?;
        let dep = plan.deploy()?;
        let chosen = plan.design;
        println!(
            "=== {} (paper design point: vec={} lane={}) ===",
            dep.device().device,
            chosen.vec_size,
            chosen.lane_num
        );
        let sweep = dep.sweep();
        println!(
            "{} grid points, {} feasible",
            sweep.points.len(),
            sweep.feasible_count()
        );
        println!(
            "{:<6}{:<6}{:>8}{:>11}{:>10}{:>12}",
            "vec", "lane", "DSPs", "time(ms)", "GOPS", "GOPS/DSP"
        );
        for p in sweep.pareto() {
            let mark = if p.params.vec_size == chosen.vec_size
                && p.params.lane_num == chosen.lane_num
            {
                "  <- paper's point"
            } else {
                ""
            };
            println!(
                "{:<6}{:<6}{:>8}{:>11.2}{:>10.1}{:>12.3}{mark}",
                p.params.vec_size,
                p.params.lane_num,
                p.usage.dsps,
                p.time_ms,
                p.gops,
                p.gops_per_dsp
            );
        }
        let lat = sweep.best_latency().unwrap();
        let den = sweep.best_density().unwrap();
        println!(
            "latency-optimal: vec={} lane={} ({:.2} ms, {} DSPs)",
            lat.params.vec_size, lat.params.lane_num, lat.time_ms,
            lat.usage.dsps
        );
        println!(
            "density-optimal: vec={} lane={} ({:.3} GOPS/DSP)",
            den.params.vec_size, den.params.lane_num, den.gops_per_dsp
        );
        // Adopt the latency winner back into the plan — the artifact a
        // follow-up simulate/serve run would consume.  (`dep` was
        // resolved from the pre-adoption plan and still holds the
        // paper's point.)
        let adopted = lat.params;
        plan.adopt(lat);
        assert_eq!(plan.design, adopted);

        // Batch-size ablation at the paper's design point.
        println!("\nbatch scaling at the paper's point:");
        println!("{:<8}{:>11}{:>10}", "batch", "ms/image", "GOPS");
        for batch in [1usize, 2, 4, 8, 16] {
            let t = dep.analytic(batch);
            println!(
                "{:<8}{:>11.2}{:>10.1}",
                batch,
                t.time_per_image_ms(),
                t.gops()
            );
        }
        println!();
    }

    // Extended sweep: precision x overlap x channel depth, timed with
    // the token-level pipeline simulator's closed-form fast path — one
    // deployment.sweep() call over the full grid.  Deeper channels buy
    // cross-group prefetch headroom (under Full) at an M20K cost, and
    // fixed point packs more MACs per DSP while shrinking the DDR
    // streams.
    println!(
        "=== precision x overlap x depth sweep (alexnet, stratix10) ==="
    );
    let plan = Plan::builder()
        .model("alexnet")
        .device("stratix10")
        .fidelity(Fidelity::PipelineFast)
        .sweep(SweepSpace::with_precision_overlap_and_depth())
        .build()?;
    let sweep = plan.deploy()?.sweep();
    println!(
        "{:<6}{:<6}{:<8}{:<10}{:<14}{:>11}{:>12}",
        "vec", "lane", "depth", "prec", "overlap", "time(ms)", "GOPS/DSP"
    );
    for p in sweep.pareto() {
        println!(
            "{:<6}{:<6}{:<8}{:<10}{:<14}{:>11.2}{:>12.3}",
            p.params.vec_size,
            p.params.lane_num,
            p.params.channel_depth,
            format!("{:?}", p.params.precision),
            format!("{:?}", p.overlap),
            p.time_ms,
            p.gops_per_dsp
        );
    }
    println!("best per precision:");
    for (prec, p) in sweep.best_latency_per_precision() {
        println!(
            "  {:<10} vec={:<3} lane={:<3} depth={:<5} {:?} -> {:.2} ms",
            format!("{prec:?}"),
            p.params.vec_size,
            p.params.lane_num,
            p.params.channel_depth,
            p.overlap,
            p.time_ms
        );
    }
    let best = sweep.best_latency().unwrap();
    println!(
        "latency-optimal: vec={} lane={} depth={} {:?} {:?} ({:.2} ms)",
        best.params.vec_size,
        best.params.lane_num,
        best.params.channel_depth,
        best.params.precision,
        best.overlap,
        best.time_ms
    );

    // Weight-cache axis at the paper's point: how much M20K to spend
    // on the fpga::mem prefetch window (the next group's weight tile
    // streaming in during the previous group's compute — the batch-1
    // FC win).  vgg16 at batch 1 is where the streams are exposed.
    println!(
        "\n=== weight-cache sweep (vgg16 b1, stratix10, Full overlap) ==="
    );
    let mut plan = Plan::builder()
        .model("vgg16")
        .device("stratix10")
        .fidelity(Fidelity::PipelineFast)
        .build()?;
    plan.sweep = SweepSpace {
        vecs: vec![16],
        lanes: vec![11],
        ..SweepSpace::with_weight_cache()
    };
    let sweep = plan.deploy()?.sweep();
    println!("{:<12}{:>11}{:>14}", "cache(KiB)", "time(ms)", "M20K(MB)");
    for (kib, p) in sweep.best_latency_per_weight_cache() {
        println!(
            "{:<12}{:>11.2}{:>14.2}",
            kib,
            p.time_ms,
            p.usage.m20k_bytes / 1e6
        );
    }
    Ok(())
}
