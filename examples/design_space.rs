//! Design-space exploration (experiment E2): sweep the accelerator's
//! (VEC_SIZE, LANE_NUM) grid on both of the paper's devices, print the
//! Pareto frontier and the chosen design points, and show how the
//! optimum shifts with batch size.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use ffcnn::fpga::device::{ARRIA10, STRATIX10};
use ffcnn::fpga::dse::{self, Fidelity, SweepSpace};
use ffcnn::fpga::timing::{
    ffcnn_arria10_params, ffcnn_stratix10_params,
};
use ffcnn::models;

fn main() {
    let model = models::alexnet();
    for (device, chosen) in [
        (&ARRIA10, ffcnn_arria10_params()),
        (&STRATIX10, ffcnn_stratix10_params()),
    ] {
        println!(
            "=== {} (paper design point: vec={} lane={}) ===",
            device.device, chosen.vec_size, chosen.lane_num
        );
        let pts = dse::explore(&model, device, 1);
        let feasible = pts.iter().filter(|p| p.feasible).count();
        println!("{} grid points, {feasible} feasible", pts.len());
        println!(
            "{:<6}{:<6}{:>8}{:>11}{:>10}{:>12}",
            "vec", "lane", "DSPs", "time(ms)", "GOPS", "GOPS/DSP"
        );
        for p in dse::pareto(&pts) {
            let mark = if p.params.vec_size == chosen.vec_size
                && p.params.lane_num == chosen.lane_num
            {
                "  <- paper's point"
            } else {
                ""
            };
            println!(
                "{:<6}{:<6}{:>8}{:>11.2}{:>10.1}{:>12.3}{mark}",
                p.params.vec_size,
                p.params.lane_num,
                p.usage.dsps,
                p.time_ms,
                p.gops,
                p.gops_per_dsp
            );
        }
        let lat = dse::best_latency(&pts).unwrap();
        let den = dse::best_density(&pts).unwrap();
        println!(
            "latency-optimal: vec={} lane={} ({:.2} ms, {} DSPs)",
            lat.params.vec_size, lat.params.lane_num, lat.time_ms,
            lat.usage.dsps
        );
        println!(
            "density-optimal: vec={} lane={} ({:.3} GOPS/DSP)",
            den.params.vec_size, den.params.lane_num, den.gops_per_dsp
        );

        // Batch-size ablation at the paper's design point.
        println!("\nbatch scaling at the paper's point:");
        println!("{:<8}{:>11}{:>10}", "batch", "ms/image", "GOPS");
        for batch in [1usize, 2, 4, 8, 16] {
            let t = ffcnn::fpga::timing::simulate_model(
                &model,
                device,
                &chosen,
                batch,
                ffcnn::fpga::timing::OverlapPolicy::WithinGroup,
            );
            println!(
                "{:<8}{:>11.2}{:>10.1}",
                batch,
                t.time_per_image_ms(),
                t.gops()
            );
        }
        println!();
    }

    // Extended sweep: overlap on/off x channel depth, timed with the
    // token-level pipeline simulator's closed-form fast path.  Deeper
    // channels buy cross-group prefetch headroom (under Full) at an
    // M20K cost the feasibility model charges.
    println!("=== overlap x channel-depth sweep (alexnet, stratix10) ===");
    let space = SweepSpace::with_overlap_and_depth();
    let pts = dse::explore_space(
        &model,
        &STRATIX10,
        1,
        Fidelity::PipelineFast,
        &space,
    );
    println!(
        "{:<6}{:<6}{:<8}{:<14}{:>11}{:>12}",
        "vec", "lane", "depth", "overlap", "time(ms)", "GOPS/DSP"
    );
    for p in dse::pareto(&pts) {
        println!(
            "{:<6}{:<6}{:<8}{:<14}{:>11.2}{:>12.3}",
            p.params.vec_size,
            p.params.lane_num,
            p.params.channel_depth,
            format!("{:?}", p.overlap),
            p.time_ms,
            p.gops_per_dsp
        );
    }
    let best = dse::best_latency(&pts).unwrap();
    println!(
        "latency-optimal: vec={} lane={} depth={} {:?} ({:.2} ms)",
        best.params.vec_size,
        best.params.lane_num,
        best.params.channel_depth,
        best.overlap,
        best.time_ms
    );
}
