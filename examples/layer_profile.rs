//! Layer/pipeline profile (experiment E3): per-fused-group breakdown of
//! compute vs DDR cycles on both devices, the fusion bandwidth saving,
//! and the analytic-vs-token-simulation agreement, for AlexNet and
//! ResNet-50 — all through the `Plan → Deployment` facade.
//!
//! ```bash
//! cargo run --release --example layer_profile
//! ```

use ffcnn::fpga::timing::OverlapPolicy;
use ffcnn::plan::Plan;
use ffcnn::Result;

fn main() -> Result<()> {
    for model_name in ["alexnet", "resnet50"] {
        for device_name in ["arria10", "stratix10"] {
            let plan = Plan::builder()
                .model(model_name)
                .device(device_name)
                .build()?;
            let dep = plan.deploy()?;
            let t = dep.analytic(1);
            let tok = dep.simulate(1);
            println!(
                "=== {} on {} === {:.2} ms | {:.1} GOPS | fusion saves \
                 {:.0}% DDR | token-sim ratio {:.3}",
                dep.model().name,
                dep.device().device,
                t.time_per_image_ms(),
                t.gops(),
                t.fusion_traffic_saving() * 100.0,
                tok.total_cycles as f64 / t.total_cycles as f64,
            );
            // Top-5 most expensive groups.
            let mut idx: Vec<usize> = (0..t.groups.len()).collect();
            idx.sort_by_key(|&i| std::cmp::Reverse(t.groups[i].cycles));
            println!(
                "  {:<34}{:>12}{:>12}{:>10}",
                "top groups", "compute(cy)", "mem(cy)", "bound"
            );
            for &i in idx.iter().take(5) {
                let g = &t.groups[i];
                println!(
                    "  {:<34}{:>12}{:>12}{:>10}",
                    g.layers.join("+"),
                    g.compute_cycles,
                    g.mem_cycles,
                    format!("{:?}", g.bound)
                );
            }
            // Compute/memory bound split.
            let mem_bound = t
                .groups
                .iter()
                .filter(|g| {
                    matches!(g.bound, ffcnn::fpga::timing::Bound::Memory)
                })
                .count();
            println!(
                "  {} groups total, {mem_bound} memory-bound\n",
                t.groups.len()
            );
        }
    }

    // Overlap policy ablation (the double-buffering design choice),
    // from both the analytic model and the token-level simulator
    // (which resolves the cross-group overlap at token granularity,
    // DDR contention included).  The deployment's simulator handle
    // re-runs under each policy without editing the plan.
    println!(
        "=== overlap policy ablation (alexnet, stratix10) ===\n\
         {:<24}{:>14}{:>14}",
        "", "analytic(ms)", "token(ms)"
    );
    let dep = Plan::builder().model("alexnet").build()?.deploy()?;
    for (name, pol) in [
        ("no overlap", OverlapPolicy::None),
        ("within-group", OverlapPolicy::WithinGroup),
        ("full cross-group", OverlapPolicy::Full),
    ] {
        let sim = dep.simulator().policy(pol);
        println!(
            "{name:<24}{:>14.2}{:>14.2}",
            sim.analytic(1).time_per_image_ms(),
            sim.run(1).time_ms()
        );
    }
    Ok(())
}
