//! Table 1 reproduction (experiment T1): print the paper's comparison
//! table with every number re-derived from the design cost models, and
//! the paper's published values alongside for reference.
//!
//! ```bash
//! cargo run --release --example table1_repro
//! ```

use ffcnn::models;
use ffcnn::report::{render_table1, table1_rows};

/// Published Table 1 values (design, time_ms, gops, dsps, density).
const PUBLISHED: [(&str, f64, f64, u32, f64); 5] = [
    ("FPGA2016a", 45.7, 31.8, 246, 0.13),
    ("FPGA2015", 21.6, 61.6, 2240, 0.027),
    ("FPGA2016b", 43.0, 33.9, 162, 0.21),
    ("This work (Arria 10)", 50.0, 58.45, 379, 0.15),
    ("This work (Stratix 10)", 21.2, 96.25, 181, 0.53),
];

fn main() {
    let model = models::alexnet();
    let rows = table1_rows(&model);
    println!(
        "Table 1 — {} ({:.2} GOPs/image)\n",
        model.name,
        model.total_ops() as f64 / 1e9
    );
    println!("{}", render_table1(&rows));

    println!("reproduced vs published (time ms | GOPS/DSP):");
    println!(
        "{:<26}{:>10}{:>12}{:>12}{:>14}",
        "design", "ours(ms)", "paper(ms)", "ours(G/D)", "paper(G/D)"
    );
    for (row, (name, pt, _pg, _pd, pdens)) in rows.iter().zip(PUBLISHED) {
        assert_eq!(row.design, name);
        println!(
            "{:<26}{:>10.1}{:>12.1}{:>12.3}{:>14.3}",
            name, row.time_ms, pt, row.gops_per_dsp, pdens
        );
    }

    // The shape checks the paper's claims rest on:
    let s10 = &rows[4];
    let a10 = &rows[3];
    assert!(
        rows[..4].iter().all(|r| s10.time_ms < r.time_ms),
        "Stratix 10 must have the best classification time"
    );
    assert!(
        rows[..4].iter().all(|r| s10.gops_per_dsp > r.gops_per_dsp),
        "Stratix 10 must have the best performance density"
    );
    assert!(
        a10.time_ms < rows[0].time_ms,
        "Arria 10 must beat the Suda OpenCL baseline on time"
    );
    println!(
        "\nshape checks passed: Stratix-10 wins time and GOPS/DSP; \
         density gap vs PipeCNN = {:.1}x (paper: {:.1}x)",
        s10.gops_per_dsp / rows[2].gops_per_dsp,
        0.53 / 0.21
    );
    println!(
        "note: the paper's own GOPS entries are mutually inconsistent \
         (time x GOPS gives a different op count per column); ours are \
         uniform ops/time — see EXPERIMENTS.md §T1."
    );
}
