//! End-to-end serving driver (experiment E4, DESIGN.md §4).
//!
//! Builds one `Plan` and boots the full coordinator from it via
//! `Deployment::serve()` — boards (PJRT engines + FPGA cycle model),
//! dynamic batchers, router — loads a real AOT'd model, and serves
//! batched synthetic requests both closed-loop (burst) and open-loop
//! (Poisson arrivals), reporting latency percentiles, throughput and
//! batching effectiveness.  The pacing and work-stealing phases are
//! plain mutations of the same plan value.  Results recorded in
//! EXPERIMENTS.md §E4.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_batch
//! # smaller/faster: FFCNN_SERVE_MODEL=tinynet FFCNN_SERVE_N=32 ...
//! ```

use ffcnn::config::{ServingConfig, ShardPolicy};
use ffcnn::coordinator::{Pace, Policy};
use ffcnn::data;
use ffcnn::plan::Plan;
use ffcnn::Result;

fn env_or(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn main() -> Result<()> {
    let model = env_or("FFCNN_SERVE_MODEL", "alexnet");
    let conv_impl = env_or("FFCNN_SERVE_IMPL", "jnp");
    let n: usize = env_or("FFCNN_SERVE_N", "48").parse()?;
    let boards: usize = env_or("FFCNN_SERVE_BOARDS", "1").parse()?;

    // One plan describes the whole serving stack; the pace/policy
    // variants below are plain mutations of the same value.
    let plan = Plan::builder()
        .model(&model)
        .device("stratix10")
        .conv_impl(&conv_impl)
        .policy(Policy::LeastOutstanding)
        .serving(ServingConfig {
            max_batch: 8,
            max_wait_ms: 4,
            boards,
            ..Default::default()
        })
        .build()?;

    let dep = plan.deploy()?;
    let in_shape = dep.model().in_shape;

    println!(
        "serve_batch: model={model} boards={boards} max_batch={} \
         requests={n}",
        plan.serving.max_batch
    );
    println!("starting service (compiling artifacts once) ...");
    let svc = dep.serve()?;

    // Warm the pipeline so compile time doesn't pollute latencies.
    let _ = svc.classify(data::synth_images(1, in_shape, 0))?;

    // --- Phase 1: closed-loop burst (max throughput, max batching) ---
    println!("\n[phase 1] closed-loop burst of {n} requests");
    let burst = data::burst_trace(n);
    let r1 = svc.run_trace(
        &burst,
        |t| data::synth_images(1, in_shape, 100 + t.id),
        0.0,
    );
    println!("{r1}");

    // --- Phase 2: open-loop Poisson arrivals near saturation --------
    // Rate set to ~80% of the burst throughput.
    let rate = (r1.throughput_rps * 0.8).max(1.0);
    println!("\n[phase 2] open-loop Poisson at {rate:.1} req/s");
    let trace = data::poisson_trace(n, rate, 11);
    let r2 = svc.run_trace(
        &trace,
        |t| data::synth_images(1, in_shape, 500 + t.id),
        1.0,
    );
    println!("{r2}");

    // --- Phase 3: simulated-FPGA pacing (board-speed serving) -------
    println!("\n[phase 3] burst with boards paced at simulated FPGA speed");
    let mut paced = plan.clone();
    paced.pace = Pace::Fpga;
    let svc_paced = paced.deploy()?.serve()?;
    let _ = svc_paced.classify(data::synth_images(1, in_shape, 0))?;
    let r3 = svc_paced.run_trace(
        &data::burst_trace(n.min(24)),
        |t| data::synth_images(1, in_shape, 900 + t.id),
        0.0,
    );
    println!("{r3}");

    // --- Phase 4: work-stealing router under the same burst ---------
    // Idle boards steal queued requests from loaded peers, so one slow
    // batch cannot strand the queue behind it.
    println!("\n[phase 4] burst with Policy::WorkStealing");
    let mut stealing = plan.clone();
    stealing.policy = Policy::WorkStealing;
    let svc_steal = stealing.deploy()?.serve()?;
    let _ = svc_steal.classify(data::synth_images(1, in_shape, 0))?;
    let r4 = svc_steal.run_trace(
        &data::burst_trace(n),
        |t| data::synth_images(1, in_shape, 1300 + t.id),
        0.0,
    );
    println!("{r4}");
    assert_eq!(r4.errors, 0, "work-stealing phase had errors");

    // --- Phase 5: multi-board batch sharding ------------------------
    // The router balances requests, but one *large batch* submitted
    // whole parks on a single board while its peers idle.
    // ShardPolicy::SplitOver splits the batch into per-board shards
    // that run concurrently and gathers the logits back in order.
    //
    // When sharding wins: large batches on idle boards — the slowest
    // shard runs ceil(B/k) images, so board time drops ~k-fold while
    // the per-shard dispatch+gather overhead stays in the tens of µs.
    // When it loses: small batches (or a busy fleet), where that
    // overhead outweighs the saved board time.  The DSE `shards`
    // dimension (`ffcnn dse --shard-sweep`) finds the break-even per
    // (model, batch).  Boards are FPGA-paced here so latencies show
    // the boards' concurrency, not the host's.
    if boards > 1 {
        println!(
            "\n[phase 5] one 32-image batch: sharded over {boards} \
             boards vs unsharded (FPGA-paced)"
        );
        let mut whole = plan.clone();
        whole.pace = Pace::Fpga;
        let mut split = whole.clone();
        split.serving.shard = ShardPolicy::SplitOver(boards);

        let flat = data::synth_images(32, in_shape, 7000);
        let svc_whole = whole.deploy()?.serve()?;
        let _ = svc_whole.classify(data::synth_images(1, in_shape, 1))?;
        let r_whole = svc_whole.classify_batch(flat.clone())?;
        let svc_split = split.deploy()?.serve()?;
        let _ = svc_split.classify(data::synth_images(1, in_shape, 1))?;
        let r_split = svc_split.classify_batch(flat)?;
        println!(
            "unsharded: {:.1} ms | sharded x{boards}: {:.1} ms \
             ({:.2}x)",
            r_whole.latency_ms,
            r_split.latency_ms,
            r_whole.latency_ms / r_split.latency_ms
        );
        assert_eq!(r_whole.batch, 32);
        assert_eq!(r_split.batch, 32);
    }

    // Sanity: everything answered, batching engaged under burst.
    assert_eq!(r1.errors, 0, "burst phase had errors");
    assert_eq!(r2.errors, 0, "poisson phase had errors");
    assert!(r1.mean_batch >= 1.0);
    println!(
        "\nE4 summary: burst {:.1} req/s (mean batch {:.2}), poisson \
         p95 {:.1} ms, paced(sim-fpga) {:.1} req/s",
        r1.throughput_rps, r1.mean_batch, r2.latency.p95_ms,
        r3.throughput_rps
    );
    Ok(())
}
