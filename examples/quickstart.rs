//! Quickstart: load an AOT artifact, classify one image, compare the
//! host numerics path with the simulated FPGA timing.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ffcnn::config::{default_artifacts_dir, RunConfig};
use ffcnn::data;
use ffcnn::fpga::timing::simulate_model;
use ffcnn::models;
use ffcnn::runtime::Engine;
use ffcnn::Result;

fn main() -> Result<()> {
    // 1. The model and the board we are simulating.
    let cfg = RunConfig {
        model: "alexnet".into(),
        device: "stratix10".into(),
        artifacts_dir: default_artifacts_dir(),
        ..Default::default()
    };
    let model = models::by_name(&cfg.model).unwrap();
    let device = cfg.device_profile()?;
    let design = cfg.design_params()?;
    println!(
        "FFCNN quickstart: {} ({:.2} GOPs/image) on {}",
        model.name,
        model.total_ops() as f64 / 1e9,
        device.device
    );

    // 2. Real numerics: the AOT HLO artifact through the PJRT runtime.
    let engine = Engine::open(&cfg.artifacts_dir)?;
    let artifact = cfg.artifact_name(1);
    println!("compiling {artifact} (cached after first run) ...");
    engine.warm(&artifact)?;

    let image = data::synth_images(1, model.in_shape, 7);
    let t0 = std::time::Instant::now();
    let logits = engine.execute(&artifact, &image)?;
    let host_ms = t0.elapsed().as_secs_f64() * 1e3;
    let pred = ffcnn::coordinator::argmax(&logits);
    println!(
        "host (PJRT CPU) inference: {host_ms:.1} ms -> class {pred} \
         (logit {:.4})",
        logits[pred]
    );

    // 3. Simulated FPGA timing: what the paper's board would report.
    let sim = simulate_model(&model, device, &design, 1, cfg.overlap);
    println!(
        "simulated {} (vec={} lane={}): {:.2} ms/image, {:.1} GOPS, \
         DDR {:.1} MB ({}% saved by kernel fusion)",
        device.name,
        design.vec_size,
        design.lane_num,
        sim.time_per_image_ms(),
        sim.gops(),
        sim.dram_bytes as f64 / 1e6,
        (sim.fusion_traffic_saving() * 100.0).round()
    );

    // 4. Correctness: the artifact must match its exported golden blob.
    let meta = engine.manifest().artifact(&artifact)?.clone();
    if meta.golden.is_some() {
        let (ginput, gexpect) = engine.manifest().read_golden(&meta)?;
        let gout = engine.execute(&artifact, &ginput)?;
        let max_err = gout
            .iter()
            .zip(&gexpect)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("golden check: max |err| = {max_err:.2e} (OK)");
        assert!(max_err < 1e-2, "golden mismatch");
    }
    Ok(())
}
