//! Quickstart: build a `Plan`, deploy it, classify one image, and
//! compare the host numerics path with the simulated FPGA timing.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ffcnn::data;
use ffcnn::plan::Plan;
use ffcnn::runtime::Engine;
use ffcnn::Result;

fn main() -> Result<()> {
    // 1. The plan: model + board + design point (device defaults),
    //    reified as one value, and its resolved deployment.
    let plan = Plan::builder().model("alexnet").device("stratix10").build()?;
    let dep = plan.deploy()?;
    let model = dep.model();
    println!(
        "FFCNN quickstart: {} ({:.2} GOPs/image) on {}",
        model.name,
        model.total_ops() as f64 / 1e9,
        dep.device().device
    );

    // 2. Real numerics: the AOT HLO artifact through the PJRT runtime.
    let engine = Engine::open(&plan.artifacts_dir)?;
    let artifact = plan.artifact_name(1);
    println!("compiling {artifact} (cached after first run) ...");
    engine.warm(&artifact)?;

    let image = data::synth_images(1, model.in_shape, 7);
    let t0 = std::time::Instant::now();
    let logits = engine.execute(&artifact, &image)?;
    let host_ms = t0.elapsed().as_secs_f64() * 1e3;
    let pred = ffcnn::coordinator::argmax(&logits);
    println!(
        "host (PJRT CPU) inference: {host_ms:.1} ms -> class {pred} \
         (logit {:.4})",
        logits[pred]
    );

    // 3. Simulated FPGA timing: what the paper's board would report.
    let sim = dep.analytic(1);
    println!(
        "simulated {} (vec={} lane={}): {:.2} ms/image, {:.1} GOPS, \
         DDR {:.1} MB ({}% saved by kernel fusion)",
        dep.device().name,
        plan.design.vec_size,
        plan.design.lane_num,
        sim.time_per_image_ms(),
        sim.gops(),
        sim.dram_bytes as f64 / 1e6,
        (sim.fusion_traffic_saving() * 100.0).round()
    );

    // 4. Correctness: the artifact must match its exported golden blob.
    let meta = engine.manifest().artifact(&artifact)?.clone();
    if meta.golden.is_some() {
        let (ginput, gexpect) = engine.manifest().read_golden(&meta)?;
        let gout = engine.execute(&artifact, &ginput)?;
        let max_err = gout
            .iter()
            .zip(&gexpect)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("golden check: max |err| = {max_err:.2e} (OK)");
        assert!(max_err < 1e-2, "golden mismatch");
    }
    Ok(())
}
