"""AOT export pipeline: manifest schema, weight blobs, golden blobs,
HLO-text interchange invariants.

Uses the tinynet quick targets into a tmpdir so the test is hermetic
and fast; the full `make artifacts` output obeys the same schema.
"""

import json
import os

import numpy as np
import pytest

from compile import aot, nets
from compile.model import param_order


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    outdir = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(
        outdir, aot.QUICK_TARGETS, seed=aot.DEFAULT_SEED, verbose=False
    )
    return outdir, manifest


def test_manifest_written_and_loadable(built):
    outdir, manifest = built
    with open(os.path.join(outdir, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["version"] == manifest["version"] == 1
    assert len(on_disk["artifacts"]) == len(aot.QUICK_TARGETS)


def test_every_artifact_file_exists(built):
    outdir, manifest = built
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(outdir, a["hlo"]))
        assert os.path.exists(os.path.join(outdir, a["weights"]))
        if a["golden"]:
            assert os.path.exists(os.path.join(outdir, a["golden"]["file"]))


def entry_arg_count(text):
    """Number of parameters of the ENTRY computation."""
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    count = 0
    for l in lines[start + 1 :]:
        if l.startswith("}"):
            break
        if " parameter(" in l:
            count += 1
    return count


def test_hlo_is_text_with_entry(built):
    """The interchange format is HLO *text* (xla_extension 0.5.1 rejects
    jax>=0.5 serialized protos) — must contain an ENTRY computation."""
    outdir, manifest = built
    for a in manifest["artifacts"]:
        with open(os.path.join(outdir, a["hlo"])) as f:
            text = f.read()
        assert "HloModule" in text
        assert "ENTRY" in text
        # weights must be arguments, not constants: packed artifacts
        # take (blob, image), per-tensor ones every param + image.
        expect = 2 if a["packed_weights"] else len(a["params"]) + 1
        assert entry_arg_count(text) == expect


def test_packed_artifact_slices_device_side(built):
    """Execute the packed forward on the *exported* blob: the in-graph
    slice offsets must reconstruct every tensor, reproducing the
    per-tensor lowering's golden output (a swapped offset or shape
    would corrupt the logits, not just the metadata)."""
    outdir, manifest = built
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    packed = by_name["tinynet_b1_jnp_pw"]
    plain = by_name["tinynet_b1_jnp"]
    assert packed["packed_weights"] and not plain["packed_weights"]
    # Same weight blob and param table: the packing is a lowering
    # detail, not a different model.
    assert packed["weights"] == plain["weights"]
    assert packed["params"] == plain["params"]

    blob = np.fromfile(
        os.path.join(outdir, packed["weights"]), dtype=np.float32
    )
    params = nets.NETS[packed["model"]].init_params(manifest["seed"])
    fn, total = aot.make_packed_fn(
        aot.Target(packed["model"], packed["batch"], packed["conv_impl"],
                   packed=True),
        params,
    )
    assert total == blob.size
    g = packed["golden"]
    raw = np.fromfile(os.path.join(outdir, g["file"]), dtype=np.float32)
    x = raw[: g["input_numel"]].reshape(packed["input"]["shape"])
    want = raw[g["input_numel"] :].reshape(packed["output"]["shape"])
    (got,) = fn(blob, x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_weight_blob_layout(built):
    """Offsets are contiguous, in param_order, and sum to the file size."""
    outdir, manifest = built
    a = manifest["artifacts"][0]
    order = param_order(nets.NETS[a["model"]].init_params(manifest["seed"]))
    assert [p["name"] for p in a["params"]] == order
    expect_off = 0
    for p in a["params"]:
        assert p["offset"] == expect_off
        assert p["numel"] == int(np.prod(p["shape"]))
        expect_off += p["numel"]
    size = os.path.getsize(os.path.join(outdir, a["weights"]))
    assert size == expect_off * 4  # f32


def test_golden_blob_roundtrip(built):
    """input+output blob sizes and the recorded l2 match the contents."""
    outdir, manifest = built
    for a in manifest["artifacts"]:
        g = a["golden"]
        if not g:
            continue
        raw = np.fromfile(
            os.path.join(outdir, g["file"]), dtype=np.float32
        )
        assert raw.size == g["input_numel"] + g["output_numel"]
        y = raw[g["input_numel"] :]
        np.testing.assert_allclose(
            np.linalg.norm(y), g["output_l2"], rtol=1e-5
        )
        np.testing.assert_allclose(
            y[:8], np.asarray(g["output_first8"], np.float32), rtol=1e-5
        )


def test_pallas_and_jnp_goldens_agree(built):
    """Same model+seed through the two conv paths -> same logits."""
    outdir, manifest = built
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    pal = by_name["tinynet_b1_pallas"]["golden"]
    jnp_ = by_name["tinynet_b1_jnp"]["golden"]
    np.testing.assert_allclose(
        pal["output_first8"], jnp_["output_first8"], rtol=1e-4, atol=1e-5
    )


def test_models_section_covers_all_nets(built):
    _, manifest = built
    assert set(manifest["models"]) == set(nets.NETS)
    for name, m in manifest["models"].items():
        assert m["total_macs"] == sum(l["macs"] for l in m["layers"])
        assert m["total_params"] == sum(l["params"] for l in m["layers"])


def test_deterministic_weights_across_builds(built, tmp_path):
    """Same seed -> byte-identical weight blobs (rust goldens rely on it)."""
    outdir, manifest = built
    out2 = str(tmp_path / "again")
    aot.build(out2, aot.QUICK_TARGETS, seed=manifest["seed"], verbose=False)
    a = manifest["artifacts"][0]["weights"]
    b1 = open(os.path.join(outdir, a), "rb").read()
    b2 = open(os.path.join(out2, a), "rb").read()
    assert b1 == b2


def test_parse_targets():
    ts = aot.parse_targets("alexnet_b1_jnp,tinynet_b2_pallas")
    assert ts[0].model == "alexnet" and ts[0].batch == 1
    assert ts[1].impl == "pallas" and ts[1].batch == 2
    assert aot.parse_targets("quick") == aot.QUICK_TARGETS
    assert aot.parse_targets("default") == aot.DEFAULT_TARGETS


def test_make_input_deterministic():
    a = aot.make_input((2, 3, 4, 4), 7)
    b = aot.make_input((2, 3, 4, 4), 7)
    c = aot.make_input((2, 3, 4, 4), 8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
