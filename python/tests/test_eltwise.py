"""Eltwise shortcut-add Pallas kernel vs plain jnp."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import eltwise


def _rand(shape, seed):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape).astype(np.float32)
    )


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize(
    "shape", [(1, 1, 1, 1), (1, 64, 8, 8), (2, 256, 7, 7), (3, 5)]
)
def test_eltwise_vs_jnp(shape, relu):
    a, b = _rand(shape, 1), _rand(shape, 2)
    got = eltwise.add(a, b, relu=relu, impl="pallas", te=64)
    want = eltwise.add(a, b, relu=relu, impl="jnp")
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_eltwise_rejects_shape_mismatch():
    with pytest.raises(ValueError, match="mismatch"):
        eltwise.add(jnp.zeros((2, 3)), jnp.zeros((3, 2)))


@pytest.mark.parametrize("te", [1, 8, 555, 1 << 20])
def test_eltwise_tile_invariance(te):
    a, b = _rand((2, 7, 5, 3), 5), _rand((2, 7, 5, 3), 6)
    got = eltwise.add(a, b, relu=True, impl="pallas", te=te)
    want = jnp.maximum(a + b, 0.0)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@given(
    n=st.integers(1, 300),
    relu=st.booleans(),
    te=st.sampled_from([8, 64, 4096]),
    seed=st.integers(0, 99),
)
@settings(max_examples=25, deadline=None)
def test_eltwise_matches_oracle_flat(n, relu, te, seed):
    a, b = _rand((n,), seed), _rand((n,), seed + 1)
    got = eltwise.add(a, b, relu=relu, impl="pallas", te=te)
    want = jnp.maximum(a + b, 0.0) if relu else a + b
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_resnet_block_uses_eltwise_kernel():
    """The residual join through the pallas kernel equals the jnp path
    (guards the nets.py wiring)."""
    from compile import nets

    p = {
        k: jnp.asarray(v)
        for k, v in nets.resnet50_init_params(3).items()
        if k.startswith("layer1.0.") or k.startswith("conv1")
    }
    x = _rand((1, 64, 8, 8), 9)
    from compile.kernels import conv as kconv

    def block(impl):
        def cv(name, xx, stride=1, pad=0, relu=False):
            return kconv.conv2d(
                xx, p[f"layer1.0.{name}.w"], p[f"layer1.0.{name}.b"],
                stride=(stride, stride), padding=(pad, pad),
                relu=relu, impl=impl,
            )

        y = cv("conv1", x, relu=True)
        y = cv("conv2", y, pad=1, relu=True)
        y = cv("conv3", y)
        sc = cv("proj", x)
        from compile.kernels import eltwise as kelt

        return kelt.add(y, sc, relu=True, impl=impl)

    np.testing.assert_allclose(
        block("pallas"), block("jnp"), rtol=1e-4, atol=1e-4
    )
