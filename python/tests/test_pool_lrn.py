"""Pooling and LRN Pallas kernels vs their naive oracles."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import lrn as klrn
from compile.kernels import pool, ref

RTOL, ATOL = 1e-5, 1e-6


def _rand(shape, seed):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape).astype(np.float32)
    )


# --- pooling ---------------------------------------------------------------

POOL_CASES = [
    # (shape, kernel, stride, padding) — the geometries in the paper's nets
    ((1, 4, 13, 13), (3, 3), (2, 2), (0, 0)),  # AlexNet overlapping pool
    ((2, 8, 14, 14), (2, 2), (2, 2), (0, 0)),  # VGG pool
    ((1, 6, 15, 15), (3, 3), (2, 2), (1, 1)),  # ResNet stem pool (padded)
    ((1, 3, 7, 7), (7, 7), (7, 7), (0, 0)),    # global pool
    ((3, 5, 9, 11), (3, 2), (2, 3), (1, 0)),   # asymmetric everything
]


@pytest.mark.parametrize("mode", ["max", "avg"])
@pytest.mark.parametrize("case", POOL_CASES, ids=lambda c: f"{c[0]}k{c[1]}")
def test_pool_vs_ref(case, mode):
    shape, k, s, p = case
    x = _rand(shape, 7)
    got = pool.pool2d(x, k, s, padding=p, mode=mode, impl="pallas", tc=4)
    want = ref.pool2d_ref(x, k, s, padding=p, mode=mode)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    got_jnp = pool.pool2d(x, k, s, padding=p, mode=mode, impl="jnp")
    np.testing.assert_allclose(got_jnp, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("tc", [1, 3, 8, 64])
def test_pool_channel_tile_invariance(tc):
    """Channel-tile size must not change results (padding logic)."""
    x = _rand((2, 5, 8, 8), 11)
    want = ref.pool2d_ref(x, (2, 2), (2, 2))
    got = pool.pool2d(x, (2, 2), (2, 2), impl="pallas", tc=tc)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_maxpool_padding_uses_neg_inf():
    """Padded cells must never win the max (even for all-negative x)."""
    x = -jnp.ones((1, 1, 4, 4), jnp.float32) * 5.0
    got = pool.pool2d(x, (3, 3), (2, 2), padding=(1, 1), impl="pallas")
    assert float(jnp.max(got)) == -5.0


def test_global_avg_pool():
    x = _rand((2, 6, 7, 7), 13)
    got = pool.global_avg_pool(x, impl="pallas", tc=4)
    np.testing.assert_allclose(
        got, jnp.mean(x, axis=(2, 3)), rtol=RTOL, atol=ATOL
    )


def test_pool_rejects_bad_mode():
    with pytest.raises(ValueError, match="unknown pool mode"):
        pool.pool2d(jnp.zeros((1, 1, 4, 4)), (2, 2), (2, 2), mode="median")


# --- LRN -------------------------------------------------------------------


@pytest.mark.parametrize("c", [1, 3, 5, 8, 96])
def test_lrn_channel_counts(c):
    """Window clamping at channel edges for any C (incl. C < n)."""
    x = _rand((1, c, 4, 4), c)
    got = klrn.lrn(x, impl="pallas", ts=8)
    want = ref.lrn_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("params", [
    dict(n=5, k=2.0, alpha=1e-4, beta=0.75),   # AlexNet values
    dict(n=3, k=1.0, alpha=2e-4, beta=0.5),
    dict(n=7, k=0.5, alpha=1e-3, beta=1.0),
])
def test_lrn_hyperparams(params):
    x = _rand((2, 9, 5, 5), 17)
    got = klrn.lrn(x, impl="pallas", ts=16, **params)
    want = ref.lrn_ref(x, **params)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    got_jnp = klrn.lrn(x, impl="jnp", **params)
    np.testing.assert_allclose(got_jnp, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("ts", [1, 7, 64, 4096])
def test_lrn_spatial_tile_invariance(ts):
    x = _rand((1, 6, 6, 6), 19)
    want = ref.lrn_ref(x)
    got = klrn.lrn(x, impl="pallas", ts=ts)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_lrn_identity_at_zero_alpha():
    """alpha=0, k=1 -> output == input (scale is exactly 1)."""
    x = _rand((1, 5, 3, 3), 23)
    got = klrn.lrn(x, alpha=0.0, k=1.0, impl="pallas", ts=4)
    np.testing.assert_allclose(got, x, rtol=0, atol=1e-7)
