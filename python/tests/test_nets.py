"""L2 network assembly: shapes, accounting, and pallas/jnp agreement.

The accounting numbers here are the contract shared with the rust model
IR (rust/src/models) and the manifest — if these change, the rust
cross-check tests must change too.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model, nets


def _params(net, seed=42):
    return {k: jnp.asarray(v) for k, v in net.init_params(seed).items()}


# --- accounting: literature-known totals ------------------------------------


def test_alexnet_totals():
    """Original (grouped) AlexNet: 0.724 GMACs = 1.45 GOPs, 61M params.

    1.45 GOPs is the count implied by the paper's Table 1 (45.7 ms at
    31.8 GOPS for FPGA2016a)."""
    t = nets.NETS["alexnet"].layer_table()
    assert model.total_macs(t) == 724_406_816
    assert model.total_params(t) == 60_965_224
    assert 1.44e9 < 2 * model.total_macs(t) < 1.46e9


def test_alexnet1c_totals():
    """Single-column CaffeNet variant: 1.135 GMACs."""
    t = nets.NETS["alexnet1c"].layer_table()
    assert abs(model.total_macs(t) - 1.135e9) < 0.01e9


def test_vgg11_totals():
    """VGG-11 (Fig. 1 model): ~7.6 GMACs, ~132.9M params."""
    t = nets.NETS["vgg11"].layer_table()
    assert abs(model.total_macs(t) - 7.609e9) < 0.02e9
    assert abs(model.total_params(t) - 132.86e6) < 0.1e6


def test_vgg16_totals():
    t = nets.NETS["vgg16"].layer_table()
    assert abs(model.total_macs(t) - 15.47e9) < 0.05e9
    assert abs(model.total_params(t) - 138.36e6) < 0.1e6


def test_resnet50_totals():
    """ResNet-50: ~3.86 GMACs, ~25.5M params."""
    t = nets.NETS["resnet50"].layer_table()
    assert abs(model.total_macs(t) - 3.858e9) < 0.03e9
    assert abs(model.total_params(t) - 25.53e6) < 0.2e6


def test_fig1_conv_fc_dominate_vgg11():
    """Fig. 1's claim: conv+fc contribute >99% of weights and ops."""
    t = nets.NETS["vgg11"].layer_table()
    conv_fc_params = sum(i.params for i in t if i.kind in ("conv", "fc"))
    conv_fc_macs = sum(i.macs for i in t if i.kind in ("conv", "fc"))
    assert conv_fc_params / model.total_params(t) > 0.99
    assert conv_fc_macs / max(model.total_macs(t), 1) > 0.99


def test_fig1_fc_holds_most_weights_conv_most_ops():
    """Fig. 1's shape: FC dominates weights, conv dominates operations."""
    t = nets.NETS["vgg11"].layer_table()
    fc_params = sum(i.params for i in t if i.kind == "fc")
    conv_macs = sum(i.macs for i in t if i.kind == "conv")
    assert fc_params / model.total_params(t) > 0.5
    assert conv_macs / model.total_macs(t) > 0.9


# --- shape propagation -------------------------------------------------------


def test_alexnet_shapes():
    t = nets.NETS["alexnet"].layer_table()
    by = {i.name: i for i in t}
    assert by["conv1"].out_shape == (96, 55, 55)
    assert by["pool1"].out_shape == (96, 27, 27)
    assert by["conv2"].out_shape == (256, 27, 27)
    assert by["pool2"].out_shape == (256, 13, 13)
    assert by["conv5"].out_shape == (256, 13, 13)
    assert by["pool5"].out_shape == (256, 6, 6)
    assert by["flatten"].out_shape == (9216,)
    assert by["fc8"].out_shape == (1000,)


def test_resnet50_shapes():
    t = nets.NETS["resnet50"].layer_table()
    by = {i.name: i for i in t}
    assert by["conv1"].out_shape == (64, 112, 112)
    assert by["pool1"].out_shape == (64, 56, 56)
    assert by["layer1.0.conv3"].out_shape == (256, 56, 56)
    assert by["layer2.0.conv3"].out_shape == (512, 28, 28)
    assert by["layer4.2.conv3"].out_shape == (2048, 7, 7)
    assert by["fc"].out_shape == (1000,)
    # 53 convs + 1 fc = the "50 layers" counting conv1 + 16*3 + fc
    assert sum(1 for i in t if i.kind == "conv") == 53


def test_resnet50_param_count_matches_table():
    """init_params tensor sizes must sum to the layer-table total."""
    p = nets.NETS["resnet50"].init_params(0)
    n = sum(int(np.prod(v.shape)) for v in p.values())
    t = nets.NETS["resnet50"].layer_table()
    assert n == model.total_params(t)


@pytest.mark.parametrize("name", ["alexnet", "vgg11", "tinynet"])
def test_chain_param_count_matches_table(name):
    p = nets.NETS[name].init_params(0)
    n = sum(int(np.prod(v.shape)) for v in p.values())
    t = nets.NETS[name].layer_table()
    assert n == model.total_params(t)


# --- forward passes ----------------------------------------------------------


def test_tinynet_forward_pallas_vs_jnp():
    net = nets.NETS["tinynet"]
    p = _params(net)
    x = jnp.asarray(
        np.random.RandomState(0).randn(2, 3, 16, 16).astype(np.float32)
    )
    a = net.forward(p, x, impl="jnp")
    b = net.forward(p, x, impl="pallas")
    assert a.shape == (2, 10)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_forward_deterministic():
    net = nets.NETS["tinynet"]
    p = _params(net)
    x = jnp.ones((1, 3, 16, 16), jnp.float32)
    y1 = net.forward(p, x, impl="pallas")
    y2 = net.forward(p, x, impl="pallas")
    np.testing.assert_allclose(y1, y2, rtol=0, atol=0)


def test_init_seed_changes_params():
    net = nets.NETS["tinynet"]
    a = net.init_params(1)["conv1.w"]
    b = net.init_params(2)["conv1.w"]
    assert np.abs(a - b).max() > 0


def test_resnet_block_forward_small():
    """One bottleneck block end-to-end at reduced spatial size."""
    p = {
        k: jnp.asarray(v)
        for k, v in nets.resnet50_init_params(7).items()
        if k.startswith("layer1.0.") or k.startswith("conv1")
    }
    x = jnp.asarray(
        np.random.RandomState(1).randn(1, 64, 8, 8).astype(np.float32)
    )

    def block(x, impl):
        from compile.kernels import conv as kconv

        def cv(name, x, stride=1, pad=0, relu=False):
            return kconv.conv2d(
                x, p[f"layer1.0.{name}.w"], p[f"layer1.0.{name}.b"],
                stride=(stride, stride), padding=(pad, pad),
                relu=relu, impl=impl,
            )

        y = cv("conv1", x, relu=True)
        y = cv("conv2", y, pad=1, relu=True)
        y = cv("conv3", y)
        sc = cv("proj", x)
        return jnp.maximum(y + sc, 0.0)

    a = block(x, "jnp")
    b = block(x, "pallas")
    assert a.shape == (1, 256, 8, 8)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_dropout_is_identity_and_softmax_normalizes():
    from compile.model import LayerSpec, chain_forward

    specs = [LayerSpec("d", "dropout"), LayerSpec("s", "softmax")]
    x = jnp.asarray([[1.0, 2.0, 3.0]])
    out = chain_forward(specs, {}, x)
    np.testing.assert_allclose(float(jnp.sum(out)), 1.0, rtol=1e-6)
