"""FC layer on the shared Pallas GEMM vs the einsum oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import fc as kfc
from compile.kernels import ref
from compile.kernels.conv import matmul_bias_act

RTOL, ATOL = 1e-4, 1e-5


def _rand(shape, seed):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape).astype(np.float32)
    )


FC_CASES = [
    # (batch, din, dout) — the paper's AlexNet head geometries (scaled)
    (1, 9216, 128),   # fc6 reduction width, narrow out for speed
    (1, 256, 1000),   # classifier out width
    (4, 4096, 64),    # batched
    (2, 1, 1),        # degenerate
    (3, 37, 19),      # primes
]


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("case", FC_CASES, ids=lambda c: f"n{c[0]}i{c[1]}o{c[2]}")
def test_fc_vs_ref(case, relu):
    n, din, dout = case
    x = _rand((n, din), 1)
    w = _rand((dout, din), 2)
    b = _rand((dout,), 3)
    got = kfc.fc(x, w, b, relu=relu, impl="pallas", tm=16, tn=16, tk=64)
    want = ref.fc_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    got_jnp = kfc.fc(x, w, b, relu=relu, impl="jnp")
    np.testing.assert_allclose(got_jnp, want, rtol=RTOL, atol=ATOL)


def test_fc_conv_share_one_kernel():
    """FC must be the same GEMM the conv path uses (paper: one Conv
    engine serves both layer types)."""
    x = _rand((2, 12), 5)
    w = _rand((7, 12), 6)
    via_fc = kfc.fc(x, w, None, impl="pallas", tm=8, tn=8, tk=8)
    via_gemm = matmul_bias_act(w, x.T, None, tm=8, tn=8, tk=8).T
    np.testing.assert_allclose(via_fc, via_gemm, rtol=0, atol=0)


def test_fc_rejects_dim_mismatch():
    with pytest.raises(ValueError, match="dim mismatch"):
        kfc.fc(jnp.zeros((1, 5)), jnp.zeros((3, 4)))


@pytest.mark.parametrize("dtype_in", [jnp.float32])
def test_fc_accumulates_fp32(dtype_in):
    """Accumulation stays fp32 (paper: full-precision direct compute)."""
    x = jnp.full((1, 4096), 1e-3, dtype_in)
    w = jnp.full((1, 4096), 1e-3, dtype_in)
    got = kfc.fc(x, w, None, impl="pallas")
    np.testing.assert_allclose(got, [[4096e-6]], rtol=1e-4)
