"""Property-based shape/param sweeps over the L1 kernels (hypothesis).

Each property asserts kernel == oracle for randomized geometry — the
breadth pass behind the fixed-geometry tests in test_kernel.py.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, fc as kfc, lrn as klrn, pool, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(shape, seed):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape).astype(np.float32)
    )


@st.composite
def conv_geometry(draw):
    n = draw(st.integers(1, 3))
    c = draw(st.integers(1, 8))
    f = draw(st.integers(1, 12))
    kh = draw(st.integers(1, 5))
    kw = draw(st.integers(1, 5))
    ph = draw(st.integers(0, 2))
    pw = draw(st.integers(0, 2))
    sh = draw(st.integers(1, 3))
    sw = draw(st.integers(1, 3))
    # input large enough for at least one output pixel
    h = draw(st.integers(max(1, kh - 2 * ph), 14))
    w = draw(st.integers(max(1, kw - 2 * pw), 14))
    h = max(h, kh - 2 * ph)
    w = max(w, kw - 2 * pw)
    return (n, c, h, w), (f, c, kh, kw), (sh, sw), (ph, pw)


@given(geo=conv_geometry(), relu=st.booleans(), seed=st.integers(0, 99))
@settings(**SETTINGS)
def test_conv_matches_oracle(geo, relu, seed):
    xs, ws, stride, pad = geo
    x, w, b = _rand(xs, seed), _rand(ws, seed + 1), _rand((ws[0],), seed + 2)
    got = conv.conv2d(
        x, w, b, stride=stride, padding=pad, relu=relu,
        impl="pallas", tm=8, tn=16, tk=8,
    )
    want = ref.conv2d_ref(x, w, b, stride=stride, padding=pad, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 60),
    n=st.integers(1, 40),
    tm=st.sampled_from([8, 16, 32]),
    tn=st.sampled_from([8, 16, 32]),
    tk=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 99),
)
@settings(**SETTINGS)
def test_gemm_tile_padding_never_leaks(m, k, n, tm, tn, tk, seed):
    """Zero-padding to tile multiples must never change the result."""
    w, p = _rand((m, k), seed), _rand((k, n), seed + 1)
    got = conv.matmul_bias_act(w, p, None, tm=tm, tn=tn, tk=tk)
    want = jnp.matmul(w, p)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@st.composite
def pool_geometry(draw):
    n = draw(st.integers(1, 2))
    c = draw(st.integers(1, 10))
    kh = draw(st.integers(1, 4))
    kw = draw(st.integers(1, 4))
    sh = draw(st.integers(1, 3))
    sw = draw(st.integers(1, 3))
    ph = draw(st.integers(0, 1))
    pw = draw(st.integers(0, 1))
    ph, pw = min(ph, kh - 1), min(pw, kw - 1)  # pad < kernel
    h = draw(st.integers(max(1, kh - 2 * ph), 12))
    w = draw(st.integers(max(1, kw - 2 * pw), 12))
    return (n, c, h, w), (kh, kw), (sh, sw), (ph, pw)


@given(
    geo=pool_geometry(),
    mode=st.sampled_from(["max", "avg"]),
    tc=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 99),
)
@settings(**SETTINGS)
def test_pool_matches_oracle(geo, mode, tc, seed):
    xs, k, s, p = geo
    x = _rand(xs, seed)
    got = pool.pool2d(x, k, s, padding=p, mode=mode, impl="pallas", tc=tc)
    want = ref.pool2d_ref(x, k, s, padding=p, mode=mode)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(
    c=st.integers(1, 24),
    hw=st.integers(1, 8),
    n=st.sampled_from([3, 5, 7]),
    ts=st.sampled_from([1, 16, 512]),
    seed=st.integers(0, 99),
)
@settings(**SETTINGS)
def test_lrn_matches_oracle(c, hw, n, ts, seed):
    x = _rand((1, c, hw, hw), seed)
    got = klrn.lrn(x, n=n, impl="pallas", ts=ts)
    want = ref.lrn_ref(x, n=n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(
    n=st.integers(1, 6),
    din=st.integers(1, 80),
    dout=st.integers(1, 50),
    relu=st.booleans(),
    seed=st.integers(0, 99),
)
@settings(**SETTINGS)
def test_fc_matches_oracle(n, din, dout, relu, seed):
    x, w, b = _rand((n, din), seed), _rand((dout, din), seed + 1), _rand((dout,), seed + 2)
    got = kfc.fc(x, w, b, relu=relu, impl="pallas", tm=8, tn=8, tk=16)
    want = ref.fc_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
