"""Pallas conv kernel vs the pure-jnp oracle — the CORE correctness signal.

The flattened 1-D convolution (FFCNN Eq. 4) must agree with the naive
shifted-view oracle for every (shape, stride, padding, groups, relu)
combination the paper's networks use, plus adversarial odd shapes that
stress the tile-padding logic.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import conv, ref

# fp32 GEMM reassociation across tile orders: relative 5e-4 over the
# deepest reduction the paper's nets use (K = C*kh*kw up to 9216).
RTOL, ATOL = 5e-4, 1e-3


def _rand(shape, seed):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape).astype(np.float32)
    )


def _check_conv(xs, ws, stride, padding, groups=1, relu=False, seed=0, **tiles):
    x = _rand(xs, seed)
    w = _rand(ws, seed + 1)
    b = _rand((ws[0],), seed + 2)
    got = conv.conv2d(
        x, w, b, stride=stride, padding=padding, relu=relu,
        groups=groups, impl="pallas", **tiles,
    )
    want = ref.conv2d_ref(
        x, w, b, stride=stride, padding=padding, relu=relu, groups=groups
    )
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    # impl="jnp" (the fast AOT path) must agree with the same oracle.
    got_jnp = conv.conv2d(
        x, w, b, stride=stride, padding=padding, relu=relu,
        groups=groups, impl="jnp",
    )
    np.testing.assert_allclose(got_jnp, want, rtol=RTOL, atol=ATOL)


# ---- the exact layer geometries of the paper's networks (scaled maps) ----

ALEXNET_LAYERS = [
    # (x, w, stride, pad, groups) with spatial dims scaled down ~4x so the
    # interpret-mode kernel stays fast; channel/kernel geometry is exact.
    ((1, 3, 59, 59), (96, 3, 11, 11), (4, 4), (0, 0), 1),
    ((1, 96, 13, 13), (256, 48, 5, 5), (1, 1), (2, 2), 2),
    ((1, 256, 7, 7), (384, 256, 3, 3), (1, 1), (1, 1), 1),
    ((1, 384, 7, 7), (384, 192, 3, 3), (1, 1), (1, 1), 2),
    ((1, 384, 7, 7), (256, 192, 3, 3), (1, 1), (1, 1), 2),
]

RESNET_LAYERS = [
    ((1, 3, 32, 32), (64, 3, 7, 7), (2, 2), (3, 3), 1),   # conv1
    ((1, 64, 14, 14), (64, 64, 1, 1), (1, 1), (0, 0), 1),  # bottleneck 1x1
    ((1, 64, 14, 14), (64, 64, 3, 3), (1, 1), (1, 1), 1),  # bottleneck 3x3
    ((1, 64, 14, 14), (256, 64, 1, 1), (1, 1), (0, 0), 1),  # expand 1x1
    ((1, 256, 14, 14), (512, 256, 1, 1), (2, 2), (0, 0), 1),  # strided proj
]


@pytest.mark.parametrize("case", ALEXNET_LAYERS, ids=lambda c: f"x{c[0]}w{c[1]}")
def test_alexnet_conv_geometry(case):
    xs, ws, stride, pad, groups = case
    _check_conv(xs, ws, stride, pad, groups=groups, relu=True)


@pytest.mark.parametrize("case", RESNET_LAYERS, ids=lambda c: f"x{c[0]}w{c[1]}")
def test_resnet_conv_geometry(case):
    xs, ws, stride, pad, groups = case
    _check_conv(xs, ws, stride, pad, groups=groups)


@pytest.mark.parametrize("batch", [1, 2, 3, 5])
def test_batch_folding(batch):
    """Batch folds into GEMM columns; result must be batch-invariant."""
    _check_conv((batch, 5, 9, 9), (7, 5, 3, 3), (1, 1), (1, 1))


@pytest.mark.parametrize(
    "tiles",
    [
        dict(tm=8, tn=8, tk=8),
        dict(tm=16, tn=32, tk=16),
        dict(tm=32, tn=128, tk=128),
        dict(tm=128, tn=128, tk=256),  # tiles larger than the problem
    ],
    ids=lambda t: f"tm{t['tm']}tn{t['tn']}tk{t['tk']}",
)
def test_tile_size_invariance(tiles):
    """Any tile choice must give identical numerics (padding logic)."""
    _check_conv((2, 6, 11, 11), (9, 6, 3, 3), (2, 2), (1, 1), **tiles)


@pytest.mark.parametrize(
    "xs,ws,stride,pad",
    [
        ((1, 1, 1, 1), (1, 1, 1, 1), (1, 1), (0, 0)),  # degenerate 1x1
        ((1, 2, 5, 7), (3, 2, 5, 7), (1, 1), (0, 0)),  # kernel == input
        ((1, 3, 8, 8), (4, 3, 3, 3), (3, 3), (0, 0)),  # stride > pad
        ((2, 7, 10, 6), (5, 7, 2, 4), (2, 1), (1, 2)),  # asymmetric all
        ((1, 13, 9, 9), (17, 13, 3, 3), (1, 1), (1, 1)),  # prime channels
    ],
)
def test_odd_shapes(xs, ws, stride, pad):
    _check_conv(xs, ws, stride, pad)


def test_relu_epilogue_clamps():
    """The fused epilogue must clamp exactly at zero."""
    x = -jnp.ones((1, 2, 4, 4), jnp.float32)
    w = jnp.ones((2, 2, 3, 3), jnp.float32)
    out = conv.conv2d(x, w, None, padding=(1, 1), relu=True, impl="pallas")
    assert float(jnp.max(out)) == 0.0
    assert float(jnp.min(out)) == 0.0


def test_bias_none_is_zero_bias():
    x = _rand((1, 3, 6, 6), 0)
    w = _rand((4, 3, 3, 3), 1)
    got = conv.conv2d(x, w, None, impl="pallas")
    want = conv.conv2d(x, w, jnp.zeros((4,), jnp.float32), impl="pallas")
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_im2col_feature_order_matches_filter_reshape():
    """im2col must order features (C major, kh, kw) = w.reshape(F,-1)."""
    x = _rand((1, 3, 5, 5), 3)
    p = conv.im2col(x, 3, 3, (1, 1), (0, 0))
    assert p.shape == (1, 27, 3, 3)
    # feature index c*9 + i*3 + j must equal x[c, y+i, x+j]
    np.testing.assert_allclose(
        p[0, 1 * 9 + 2 * 3 + 1, 1, 1], x[0, 1, 1 + 2, 1 + 1], rtol=0, atol=0
    )


def test_matmul_rejects_mismatched_k():
    with pytest.raises(ValueError, match="reduction mismatch"):
        conv.matmul_bias_act(jnp.zeros((2, 3)), jnp.zeros((4, 5)))


def test_conv_rejects_channel_mismatch():
    with pytest.raises(ValueError, match="channel mismatch"):
        conv.conv2d(jnp.zeros((1, 3, 4, 4)), jnp.zeros((2, 4, 3, 3)))


def test_conv_rejects_bad_groups():
    with pytest.raises(ValueError, match="not divisible"):
        conv.conv2d(
            jnp.zeros((1, 4, 4, 4)), jnp.zeros((3, 2, 3, 3)), groups=2
        )


def test_out_shape_helper():
    assert conv.conv_out_shape((227, 227), 11, 11, (4, 4), (0, 0)) == (55, 55)
    assert conv.conv_out_shape((13, 13), 3, 3, (1, 1), (1, 1)) == (13, 13)
    assert conv.conv_out_shape((6, 6), 3, 3, (2, 2), (0, 0)) == (2, 2)
