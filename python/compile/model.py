"""L2: CNN graph assembly on top of the L1 kernels.

The paper's accelerator executes a CNN as a sequence of layer groups,
each group flowing MemRd -> Conv -> (ReLU) -> (LRN) -> (Pool) -> MemWr
through on-chip channels.  This module mirrors that structure in JAX:

- ``LayerSpec``     — one pipeline stage (conv / pool / lrn / fc / ...).
- ``propagate``     — static shape/MACs/params accounting used for the
                      manifest, Fig. 1, and the rust-side cross-check.
- ``chain_forward`` — executes a chain net (AlexNet, VGG) calling the
                      L1 kernels with the chosen ``impl``.

ResNet's DAG (eltwise shortcuts) is assembled in ``nets.py`` from the
same kernel calls; its layer table is synthesized with the same
accounting helpers so every model reports MACs/params identically.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .kernels import conv as kconv
from .kernels import fc as kfc
from .kernels import lrn as klrn
from .kernels import pool as kpool


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One stage of a chain network.

    kind: conv | pool | lrn | fc | flatten | relu | softmax | dropout
    Conv/fc carry ``relu`` so the activation fuses into the GEMM
    epilogue, exactly like the paper's channel-fused ReLU.
    """

    name: str
    kind: str
    out_ch: int = 0  # conv filters / fc outputs
    kernel: Tuple[int, int] = (1, 1)
    stride: Tuple[int, int] = (1, 1)
    padding: Tuple[int, int] = (0, 0)
    relu: bool = False
    groups: int = 1
    pool_mode: str = "max"
    lrn_n: int = 5
    lrn_k: float = 2.0
    lrn_alpha: float = 1e-4
    lrn_beta: float = 0.75


@dataclasses.dataclass
class LayerInfo:
    """Accounting row for one layer: the numbers behind Fig. 1 / GOPS."""

    name: str
    kind: str
    in_shape: Tuple[int, ...]  # (C, H, W) or (F,)
    out_shape: Tuple[int, ...]
    macs: int  # multiply-accumulates (1 MAC = 2 ops, paper counts GOPs)
    params: int  # weights + biases

    @property
    def ops(self) -> int:
        return 2 * self.macs

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "in_shape": list(self.in_shape),
            "out_shape": list(self.out_shape),
            "macs": self.macs,
            "params": self.params,
            "ops": self.ops,
        }


def propagate(
    specs: Sequence[LayerSpec], in_shape: Tuple[int, int, int]
) -> List[LayerInfo]:
    """Static shape propagation + exact MAC/param accounting.

    in_shape: (C, H, W) without the batch dimension.  MACs are per
    single image — multiply by batch for batched GOPs.
    """
    infos: List[LayerInfo] = []
    shape: Tuple[int, ...] = in_shape
    for s in specs:
        if s.kind == "conv":
            c, h, w = shape
            oh, ow = kconv.conv_out_shape(
                (h, w), s.kernel[0], s.kernel[1], s.stride, s.padding
            )
            out = (s.out_ch, oh, ow)
            cg = c // s.groups
            macs = s.out_ch * cg * s.kernel[0] * s.kernel[1] * oh * ow
            params = s.out_ch * cg * s.kernel[0] * s.kernel[1] + s.out_ch
        elif s.kind == "pool":
            c, h, w = shape
            oh, ow = kconv.conv_out_shape(
                (h, w), s.kernel[0], s.kernel[1], s.stride, s.padding
            )
            out = (c, oh, ow)
            # comparisons/adds, not MACs; paper counts conv+fc only, we
            # track pooling work separately as 0 MACs (it shows up in the
            # cycle model, not the GOPs number).
            macs = 0
            params = 0
        elif s.kind == "lrn":
            out = shape
            macs = 0
            params = 0
        elif s.kind == "flatten":
            out = (int(np.prod(shape)),)
            macs = 0
            params = 0
        elif s.kind == "fc":
            (din,) = shape
            out = (s.out_ch,)
            macs = s.out_ch * din
            params = s.out_ch * din + s.out_ch
        elif s.kind in ("relu", "softmax", "dropout"):
            out = shape
            macs = 0
            params = 0
        else:
            raise ValueError(f"unknown layer kind {s.kind!r}")
        infos.append(
            LayerInfo(
                name=s.name,
                kind=s.kind,
                in_shape=tuple(shape),
                out_shape=tuple(out),
                macs=macs,
                params=params,
            )
        )
        shape = out
    return infos


def he_conv(rng: np.random.RandomState, f, c, kh, kw) -> np.ndarray:
    fan_in = c * kh * kw
    return (rng.randn(f, c, kh, kw) * np.sqrt(2.0 / fan_in)).astype(
        np.float32
    )


def he_fc(rng: np.random.RandomState, dout, din) -> np.ndarray:
    return (rng.randn(dout, din) * np.sqrt(2.0 / din)).astype(np.float32)


def init_chain_params(
    specs: Sequence[LayerSpec],
    in_shape: Tuple[int, int, int],
    seed: int,
) -> Dict[str, np.ndarray]:
    """He-initialized parameters for a chain net, keyed '<layer>.w/.b'."""
    rng = np.random.RandomState(seed)
    infos = propagate(specs, in_shape)
    params: Dict[str, np.ndarray] = {}
    for s, info in zip(specs, infos):
        if s.kind == "conv":
            c = info.in_shape[0] // s.groups
            params[f"{s.name}.w"] = he_conv(
                rng, s.out_ch, c, s.kernel[0], s.kernel[1]
            )
            params[f"{s.name}.b"] = np.zeros(s.out_ch, dtype=np.float32)
        elif s.kind == "fc":
            (din,) = info.in_shape
            params[f"{s.name}.w"] = he_fc(rng, s.out_ch, din)
            params[f"{s.name}.b"] = np.zeros(s.out_ch, dtype=np.float32)
    return params


def chain_forward(
    specs: Sequence[LayerSpec],
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    *,
    impl: str = "jnp",
    interpret: bool = True,
) -> jnp.ndarray:
    """Run a chain network.  x: [N, C, H, W] -> logits [N, classes].

    impl selects the kernel path for every conv/fc/pool/lrn stage:
    "pallas" is the paper's pipeline on the L1 kernels, "jnp" the fast
    XLA path used for full-resolution AOT artifacts.
    """
    for s in specs:
        if s.kind == "conv":
            x = kconv.conv2d(
                x,
                params[f"{s.name}.w"],
                params[f"{s.name}.b"],
                stride=s.stride,
                padding=s.padding,
                relu=s.relu,
                groups=s.groups,
                impl=impl,
                interpret=interpret,
            )
        elif s.kind == "pool":
            x = kpool.pool2d(
                x,
                s.kernel,
                s.stride,
                padding=s.padding,
                mode=s.pool_mode,
                impl=impl,
                interpret=interpret,
            )
        elif s.kind == "lrn":
            x = klrn.lrn(
                x,
                n=s.lrn_n,
                k=s.lrn_k,
                alpha=s.lrn_alpha,
                beta=s.lrn_beta,
                impl=impl,
                interpret=interpret,
            )
        elif s.kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif s.kind == "fc":
            x = kfc.fc(
                x,
                params[f"{s.name}.w"],
                params[f"{s.name}.b"],
                relu=s.relu,
                impl=impl,
                interpret=interpret,
            )
        elif s.kind == "relu":
            x = jnp.maximum(x, 0.0)
        elif s.kind == "softmax":
            z = x - jnp.max(x, axis=-1, keepdims=True)
            e = jnp.exp(z)
            x = e / jnp.sum(e, axis=-1, keepdims=True)
        elif s.kind == "dropout":
            pass  # inference: identity
        else:
            raise ValueError(f"unknown layer kind {s.kind!r}")
    return x


def param_order(params: Dict[str, np.ndarray]) -> List[str]:
    """Deterministic parameter ordering for the AOT calling convention.

    Insertion order of the dict (python 3.7+) — the same order the
    manifest records and the rust runtime feeds literals in.
    """
    return list(params.keys())


def total_macs(infos: Sequence[LayerInfo]) -> int:
    return sum(i.macs for i in infos)


def total_params(infos: Sequence[LayerInfo]) -> int:
    return sum(i.params for i in infos)
