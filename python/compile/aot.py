"""AOT export: lower L2 graphs to HLO text + weight blobs + manifest.

This is the single build-time python entrypoint (``make artifacts``).
It emits, under ``artifacts/``:

- ``<artifact>.hlo.txt``     — HLO **text** for the rust PJRT runtime.
  Text, NOT ``.serialize()``: jax >= 0.5 emits HloModuleProto with
  64-bit instruction ids which xla_extension 0.5.1 rejects
  (``proto.id() <= INT_MAX``); the text parser reassigns ids and
  round-trips cleanly (see /opt/xla-example/README.md).
- ``<model>.weights.bin``    — all parameters, float32 little-endian,
  concatenated in AOT argument order (shared by every batch/impl
  variant of the model).
- ``<artifact>.golden.bin``  — deterministic input + expected output
  blobs for rust integration tests (jnp-impl artifacts only).
- ``manifest.json``          — artifact index: HLO/weights/golden paths,
  parameter order + shapes + offsets, input/output shapes, plus the
  per-model layer tables (MACs/params) the rust IR cross-checks.

The lowered function signature is ``f(*params, image) -> (logits,)``
(weights are *arguments*, never baked constants — constants would blow
up the HLO text by hundreds of MB).
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import param_order, total_macs, total_params
from .nets import NETS

DEFAULT_SEED = 20220414  # FFCNN arXiv date


@dataclasses.dataclass(frozen=True)
class Target:
    """One AOT artifact to produce."""

    model: str
    batch: int
    impl: str  # "jnp" | "pallas"
    golden: bool = False  # also emit input/output golden blobs
    #: lower with ONE flat weight-blob argument that the graph slices
    #: per tensor device-side (the runtime then uploads a single buffer
    #: per model instead of one per parameter tensor)
    packed: bool = False

    @property
    def name(self) -> str:
        suffix = "_pw" if self.packed else ""
        return f"{self.model}_b{self.batch}_{self.impl}{suffix}"


#: ``make artifacts`` default set.  Full-resolution nets use the jnp conv
#: path (DESIGN.md §8); the pallas path covers tinynet end-to-end and
#: full AlexNet at batch 1 (kernel-identical to the paper's pipeline).
DEFAULT_TARGETS: List[Target] = [
    Target("tinynet", 1, "pallas", golden=True),
    Target("tinynet", 2, "pallas", golden=True),
    Target("tinynet", 1, "jnp", golden=True),
    Target("alexnet", 1, "jnp", golden=True),
    Target("alexnet", 4, "jnp", golden=True),
    Target("alexnet", 8, "jnp"),
    Target("alexnet", 1, "pallas"),
    Target("resnet50", 1, "jnp", golden=True),
    Target("resnet50", 4, "jnp"),
    # Packed-weights variants: ResNet-50 is the 200+-tensor model whose
    # warm-up the single-blob upload is for.  Both serving batch sizes
    # are exported packed so the coordinator can adopt the layout
    # wholesale (it refuses to mix layouts — that would keep two
    # device-resident copies of the weights).
    Target("resnet50", 1, "jnp", golden=True, packed=True),
    Target("resnet50", 4, "jnp", packed=True),
]

#: fast subset used by pytest smoke tests.
QUICK_TARGETS: List[Target] = [
    Target("tinynet", 1, "pallas", golden=True),
    Target("tinynet", 1, "jnp", golden=True),
    Target("tinynet", 1, "jnp", golden=True, packed=True),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def make_input(shape: Tuple[int, ...], seed: int) -> np.ndarray:
    """Deterministic synthetic image batch (the paper verifies
    functional correctness, not accuracy — see DESIGN.md §2)."""
    rng = np.random.RandomState(seed)
    return (rng.randn(*shape) * 0.1).astype(np.float32)


def export_weights(
    outdir: str, model: str, params: Dict[str, np.ndarray]
) -> Tuple[str, List[dict]]:
    """Write the concatenated f32 weight blob; return path + index."""
    path = os.path.join(outdir, f"{model}.weights.bin")
    index = []
    offset = 0
    with open(path, "wb") as f:
        for name in param_order(params):
            a = np.ascontiguousarray(params[name], dtype=np.float32)
            f.write(a.tobytes())
            index.append(
                {
                    "name": name,
                    "shape": list(a.shape),
                    "offset": offset,  # in elements
                    "numel": int(a.size),
                }
            )
            offset += int(a.size)
    return os.path.basename(path), index


def make_packed_fn(t: Target, params: Dict[str, np.ndarray]):
    """The packed-weights forward: ONE flat f32 blob argument, every
    tensor a static slice + reshape *inside the graph* (device-side
    views), so the runtime uploads the blob exactly once per model.

    Returns (fn(blob, image) -> (logits,), blob_numel).  Exposed so
    tests can execute the slicing logic directly against the exported
    blob (the offsets here must match ``export_weights``).
    """
    net = NETS[t.model]
    names = param_order(params)
    sizes = [int(params[n].size) for n in names]
    offsets = []
    off = 0
    for s in sizes:
        offsets.append(off)
        off += s

    def fn(blob, image):
        ps = {}
        for n, o, s in zip(names, offsets, sizes):
            ps[n] = jax.lax.slice(blob, (o,), (o + s,)).reshape(
                params[n].shape
            )
        return (net.forward(ps, image, impl=t.impl, interpret=True),)

    return fn, off


def lower_target(
    t: Target, params: Dict[str, np.ndarray]
) -> Tuple[str, Tuple[int, ...], Tuple[int, ...]]:
    """Lower one artifact; returns (hlo_text, in_shape, out_shape)."""
    net = NETS[t.model]
    names = param_order(params)
    in_shape = (t.batch,) + net.in_shape

    if t.packed:
        fn, total = make_packed_fn(t, params)
        specs = [
            jax.ShapeDtypeStruct((total,), jnp.float32),
            jax.ShapeDtypeStruct(in_shape, jnp.float32),
        ]
    else:

        def fn(*args):
            ps = dict(zip(names, args[:-1]))
            return (
                net.forward(ps, args[-1], impl=t.impl, interpret=True),
            )

        specs = [
            jax.ShapeDtypeStruct(params[n].shape, jnp.float32)
            for n in names
        ] + [jax.ShapeDtypeStruct(in_shape, jnp.float32)]

    lowered = jax.jit(fn).lower(*specs)
    hlo = to_hlo_text(lowered)

    # Output shape from the net's layer table tail (always [N, classes]).
    out_shape = (t.batch, net.layer_table()[-1].out_shape[-1])
    return hlo, in_shape, out_shape


def run_golden(
    t: Target, params: Dict[str, np.ndarray], x: np.ndarray
) -> np.ndarray:
    """Execute the artifact function once in-process for golden outputs."""
    net = NETS[t.model]
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    fwd = jax.jit(
        lambda xx: net.forward(jp, xx, impl=t.impl, interpret=True)
    )
    return np.asarray(fwd(jnp.asarray(x)))


def build(
    outdir: str,
    targets: List[Target],
    seed: int = DEFAULT_SEED,
    verbose: bool = True,
) -> dict:
    """Produce all artifacts + manifest; returns the manifest dict."""
    os.makedirs(outdir, exist_ok=True)
    manifest: dict = {
        "version": 1,
        "seed": seed,
        "artifacts": [],
        "models": {},
    }

    # Per-model layer tables (all nets, artifact or not): the accounting
    # contract cross-checked by rust/src/models tests.
    for name, net in NETS.items():
        table = net.layer_table()
        manifest["models"][name] = {
            "in_shape": list(net.in_shape),
            "layers": [i.to_json() for i in table],
            "total_macs": total_macs(table),
            "total_params": total_params(table),
        }

    params_cache: Dict[str, Dict[str, np.ndarray]] = {}
    weights_meta: Dict[str, Tuple[str, List[dict]]] = {}

    for t in targets:
        if t.model not in params_cache:
            params_cache[t.model] = NETS[t.model].init_params(seed)
            weights_meta[t.model] = export_weights(
                outdir, t.model, params_cache[t.model]
            )
            if verbose:
                nbytes = sum(
                    p.size * 4 for p in params_cache[t.model].values()
                )
                print(
                    f"[aot] weights {t.model}: {nbytes / 1e6:.1f} MB "
                    f"({len(params_cache[t.model])} tensors)"
                )
        params = params_cache[t.model]

        if verbose:
            print(f"[aot] lowering {t.name} ...")
        hlo, in_shape, out_shape = lower_target(t, params)
        hlo_name = f"{t.name}.hlo.txt"
        with open(os.path.join(outdir, hlo_name), "w") as f:
            f.write(hlo)

        entry = {
            "name": t.name,
            "model": t.model,
            "batch": t.batch,
            "conv_impl": t.impl,
            "hlo": hlo_name,
            "hlo_sha256": hashlib.sha256(hlo.encode()).hexdigest(),
            "weights": weights_meta[t.model][0],
            "params": weights_meta[t.model][1],
            "packed_weights": t.packed,
            "input": {"shape": list(in_shape), "dtype": "f32"},
            "output": {"shape": list(out_shape), "dtype": "f32"},
            "golden": None,
        }

        if t.golden:
            x = make_input(in_shape, seed ^ (t.batch * 7919))
            y = run_golden(t, params, x)
            gname = f"{t.name}.golden.bin"
            with open(os.path.join(outdir, gname), "wb") as f:
                f.write(x.tobytes())
                f.write(np.ascontiguousarray(y, np.float32).tobytes())
            entry["golden"] = {
                "file": gname,
                "input_numel": int(x.size),
                "output_numel": int(y.size),
                "output_l2": float(np.linalg.norm(y)),
                "output_first8": [float(v) for v in y.reshape(-1)[:8]],
            }
            if verbose:
                print(
                    f"[aot]   golden {t.name}: |y|2={entry['golden']['output_l2']:.4f}"
                )

        manifest["artifacts"].append(entry)
        if verbose:
            print(f"[aot]   wrote {hlo_name} ({len(hlo) / 1e6:.2f} MB)")

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts")
    return manifest


def parse_targets(spec: str) -> List[Target]:
    if spec == "default":
        return DEFAULT_TARGETS
    if spec == "quick":
        return QUICK_TARGETS
    out = []
    for part in spec.split(","):
        model, b, impl = part.rsplit("_", 2)
        out.append(Target(model, int(b.lstrip("b")), impl, golden=True))
    return out


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--targets",
        default="default",
        help='"default", "quick", or comma list like "alexnet_b1_jnp"',
    )
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED)
    args = ap.parse_args(argv)
    build(args.outdir, parse_targets(args.targets), seed=args.seed)


if __name__ == "__main__":
    main()
