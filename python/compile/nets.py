"""Network definitions: AlexNet, VGG-11/16, ResNet-50, TinyNet.

These are the models the paper evaluates (AlexNet 8 layers, ResNet-50
50 layers) plus VGG-11 for the Fig. 1 weight/operation distribution and
a TinyNet used by fast integration tests on the rust side.

Each entry in ``NETS`` provides:
- ``specs`` / ``forward``   — the jax forward pass over L1 kernels;
- ``init_params(seed)``     — deterministic He-initialized weights
                              (numpy, float32) in AOT argument order;
- ``layer_table(in_shape)`` — accounting rows (MACs, params, shapes)
                              shared with the manifest and cross-checked
                              by the rust model IR.

ResNet-50 batch-norms are *folded into the conv weights at init time*
(inference-only, as the paper deploys), so exported params are plain
(w, b) pairs.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from .kernels import conv as kconv
from .kernels import eltwise as kelt
from .kernels import fc as kfc
from .kernels import pool as kpool
from .model import (
    LayerInfo,
    LayerSpec,
    chain_forward,
    he_conv,
    he_fc,
    init_chain_params,
    propagate,
)

# --------------------------------------------------------------------------
# AlexNet — original two-column variant (groups=2 on conv2/4/5), 227x227.
# 0.727 GMACs = 1.45 GOPs, the count the paper's Table 1 GOPS figures
# imply (45.7 ms @ 31.8 GOPS etc.).  ``alexnet1c`` below is the
# single-column CaffeNet variant (1.135 GMACs) kept for ablations.
# --------------------------------------------------------------------------

ALEXNET_IN = (3, 227, 227)


def _alexnet_specs(groups: int) -> List[LayerSpec]:
    g = groups
    return [
        LayerSpec("conv1", "conv", 96, (11, 11), (4, 4), (0, 0), relu=True),
        LayerSpec("norm1", "lrn"),
        LayerSpec("pool1", "pool", kernel=(3, 3), stride=(2, 2)),
        LayerSpec(
            "conv2", "conv", 256, (5, 5), (1, 1), (2, 2), relu=True, groups=g
        ),
        LayerSpec("norm2", "lrn"),
        LayerSpec("pool2", "pool", kernel=(3, 3), stride=(2, 2)),
        LayerSpec("conv3", "conv", 384, (3, 3), (1, 1), (1, 1), relu=True),
        LayerSpec(
            "conv4", "conv", 384, (3, 3), (1, 1), (1, 1), relu=True, groups=g
        ),
        LayerSpec(
            "conv5", "conv", 256, (3, 3), (1, 1), (1, 1), relu=True, groups=g
        ),
        LayerSpec("pool5", "pool", kernel=(3, 3), stride=(2, 2)),
        LayerSpec("flatten", "flatten"),
        LayerSpec("fc6", "fc", 4096, relu=True),
        LayerSpec("fc7", "fc", 4096, relu=True),
        LayerSpec("fc8", "fc", 1000),
    ]


ALEXNET_SPECS = _alexnet_specs(groups=2)
ALEXNET1C_SPECS = _alexnet_specs(groups=1)

# --------------------------------------------------------------------------
# VGG-11 (configuration A) and VGG-16 (configuration D), 224x224 input.
# --------------------------------------------------------------------------

VGG_IN = (3, 224, 224)


def _vgg_specs(cfg: List) -> List[LayerSpec]:
    specs: List[LayerSpec] = []
    ci = 0
    pi = 0
    for v in cfg:
        if v == "M":
            pi += 1
            specs.append(
                LayerSpec(f"pool{pi}", "pool", kernel=(2, 2), stride=(2, 2))
            )
        else:
            ci += 1
            specs.append(
                LayerSpec(
                    f"conv{ci}", "conv", v, (3, 3), (1, 1), (1, 1), relu=True
                )
            )
    specs += [
        LayerSpec("flatten", "flatten"),
        LayerSpec("fc6", "fc", 4096, relu=True),
        LayerSpec("fc7", "fc", 4096, relu=True),
        LayerSpec("fc8", "fc", 1000),
    ]
    return specs


VGG11_SPECS = _vgg_specs(
    [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]
)
VGG16_SPECS = _vgg_specs(
    [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
     512, 512, 512, "M", 512, 512, 512, "M"]
)

# --------------------------------------------------------------------------
# TinyNet — a fast 2-conv net on 3x16x16 inputs for integration tests.
# --------------------------------------------------------------------------

TINYNET_IN = (3, 16, 16)

TINYNET_SPECS: List[LayerSpec] = [
    LayerSpec("conv1", "conv", 8, (3, 3), (1, 1), (1, 1), relu=True),
    LayerSpec("pool1", "pool", kernel=(2, 2), stride=(2, 2)),
    LayerSpec("conv2", "conv", 16, (3, 3), (1, 1), (1, 1), relu=True),
    LayerSpec("pool2", "pool", kernel=(2, 2), stride=(2, 2)),
    LayerSpec("flatten", "flatten"),
    LayerSpec("fc1", "fc", 32, relu=True),
    LayerSpec("fc2", "fc", 10),
]

# --------------------------------------------------------------------------
# ResNet-50 (v1, stride on the first 1x1 of a downsampling block).
# BN folded into conv at init; eltwise-add shortcuts; 224x224 input.
# --------------------------------------------------------------------------

RESNET50_IN = (3, 224, 224)
_R50_STAGES = [  # (blocks, mid_channels, out_channels, first_stride)
    (3, 64, 256, 1),
    (4, 128, 512, 2),
    (6, 256, 1024, 2),
    (3, 512, 2048, 2),
]


def _fold_bn(
    rng: np.random.RandomState, w: np.ndarray, b: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Fold a randomly-initialized BN into (w, b).

    Inference-time BN is an affine per-out-channel transform
    y = gamma*(x-mean)/sqrt(var+eps) + beta; folding multiplies each
    filter by s=gamma/sqrt(var+eps) and shifts the bias.  Random
    (but seeded) BN statistics keep the folded network numerically
    non-trivial.
    """
    f = w.shape[0]
    gamma = rng.uniform(0.5, 1.5, f).astype(np.float32)
    beta = (rng.randn(f) * 0.05).astype(np.float32)
    mean = (rng.randn(f) * 0.05).astype(np.float32)
    var = rng.uniform(0.5, 1.5, f).astype(np.float32)
    s = gamma / np.sqrt(var + 1e-5)
    return w * s.reshape(f, 1, 1, 1), (b - mean) * s + beta


def _r50_block_names() -> List[Tuple[str, int, int, int, int, bool]]:
    """(prefix, in_ch, mid, out, stride, has_projection) per block."""
    rows = []
    in_ch = 64
    for si, (blocks, mid, out, stride0) in enumerate(_R50_STAGES, start=1):
        for bi in range(blocks):
            stride = stride0 if bi == 0 else 1
            proj = bi == 0
            rows.append((f"layer{si}.{bi}", in_ch, mid, out, stride, proj))
            in_ch = out
    return rows


def resnet50_init_params(seed: int) -> Dict[str, np.ndarray]:
    rng = np.random.RandomState(seed)
    p: Dict[str, np.ndarray] = {}

    def conv_bn(name: str, f: int, c: int, k: int) -> None:
        w = he_conv(rng, f, c, k, k)
        b = np.zeros(f, dtype=np.float32)
        p[f"{name}.w"], p[f"{name}.b"] = _fold_bn(rng, w, b)

    conv_bn("conv1", 64, 3, 7)
    for prefix, in_ch, mid, out, _stride, proj in _r50_block_names():
        conv_bn(f"{prefix}.conv1", mid, in_ch, 1)
        conv_bn(f"{prefix}.conv2", mid, mid, 3)
        conv_bn(f"{prefix}.conv3", out, mid, 1)
        if proj:
            conv_bn(f"{prefix}.proj", out, in_ch, 1)
    p["fc.w"] = he_fc(rng, 1000, 2048)
    p["fc.b"] = np.zeros(1000, dtype=np.float32)
    return p


def resnet50_forward(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    *,
    impl: str = "jnp",
    interpret: bool = True,
) -> jnp.ndarray:
    """ResNet-50 inference pass over the L1 kernels."""

    def cv(name, x, stride=1, pad=0, relu=False):
        return kconv.conv2d(
            x,
            params[f"{name}.w"],
            params[f"{name}.b"],
            stride=(stride, stride),
            padding=(pad, pad),
            relu=relu,
            impl=impl,
            interpret=interpret,
        )

    x = cv("conv1", x, stride=2, pad=3, relu=True)
    x = kpool.pool2d(
        x, (3, 3), (2, 2), padding=(1, 1), mode="max",
        impl=impl, interpret=interpret,
    )
    for prefix, _in_ch, _mid, _out, stride, proj in _r50_block_names():
        identity = x
        y = cv(f"{prefix}.conv1", x, stride=stride, relu=True)
        y = cv(f"{prefix}.conv2", y, pad=1, relu=True)
        y = cv(f"{prefix}.conv3", y)
        if proj:
            identity = cv(f"{prefix}.proj", x, stride=stride)
        # eltwise add + ReLU (the pallas kernel when impl="pallas")
        x = kelt.add(y, identity, relu=True, impl=impl, interpret=interpret)
    x = kpool.global_avg_pool(x, impl=impl, interpret=interpret)
    return kfc.fc(
        x, params["fc.w"], params["fc.b"], impl=impl, interpret=interpret
    )


def resnet50_layer_table(in_shape=RESNET50_IN) -> List[LayerInfo]:
    """Accounting rows for ResNet-50, same schema as chain nets."""
    infos: List[LayerInfo] = []
    c, h, w = in_shape

    def add_conv(name, in_c, out_c, k, stride, pad, hw):
        oh, ow = kconv.conv_out_shape(hw, k, k, (stride, stride), (pad, pad))
        infos.append(
            LayerInfo(
                name=name,
                kind="conv",
                in_shape=(in_c, hw[0], hw[1]),
                out_shape=(out_c, oh, ow),
                macs=out_c * in_c * k * k * oh * ow,
                params=out_c * in_c * k * k + out_c,
            )
        )
        return oh, ow

    hw = (h, w)
    hw = add_conv("conv1", 3, 64, 7, 2, 3, hw)
    oh, ow = kconv.conv_out_shape(hw, 3, 3, (2, 2), (1, 1))
    infos.append(
        LayerInfo("pool1", "pool", (64,) + hw, (64, oh, ow), 0, 0)
    )
    hw = (oh, ow)
    for prefix, in_ch, mid, out, stride, proj in _r50_block_names():
        in_hw = hw
        hw = add_conv(f"{prefix}.conv1", in_ch, mid, 1, stride, 0, hw)
        hw = add_conv(f"{prefix}.conv2", mid, mid, 3, 1, 1, hw)
        hw = add_conv(f"{prefix}.conv3", mid, out, 1, 1, 0, hw)
        if proj:
            add_conv(f"{prefix}.proj", in_ch, out, 1, stride, 0, in_hw)
        infos.append(
            LayerInfo(
                f"{prefix}.add", "eltwise", (out,) + hw, (out,) + hw, 0, 0
            )
        )
    infos.append(LayerInfo("avgpool", "pool", (2048,) + hw, (2048,), 0, 0))
    infos.append(
        LayerInfo(
            "fc", "fc", (2048,), (1000,),
            macs=1000 * 2048, params=1000 * 2048 + 1000,
        )
    )
    return infos


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------


class Net:
    """Uniform wrapper: chain nets and ResNet expose the same surface."""

    def __init__(
        self,
        name: str,
        in_shape: Tuple[int, int, int],
        init_params: Callable[[int], Dict[str, np.ndarray]],
        forward: Callable,
        layer_table: Callable[[], List[LayerInfo]],
    ):
        self.name = name
        self.in_shape = in_shape
        self.init_params = init_params
        self.forward = forward
        self.layer_table = layer_table


def _chain_net(name, specs, in_shape, seed_base=0) -> Net:
    return Net(
        name=name,
        in_shape=in_shape,
        init_params=lambda seed: init_chain_params(specs, in_shape, seed),
        forward=lambda params, x, impl="jnp", interpret=True: chain_forward(
            specs, params, x, impl=impl, interpret=interpret
        ),
        layer_table=lambda: propagate(specs, in_shape),
    )


NETS: Dict[str, Net] = {
    "alexnet": _chain_net("alexnet", ALEXNET_SPECS, ALEXNET_IN),
    "alexnet1c": _chain_net("alexnet1c", ALEXNET1C_SPECS, ALEXNET_IN),
    "vgg11": _chain_net("vgg11", VGG11_SPECS, VGG_IN),
    "vgg16": _chain_net("vgg16", VGG16_SPECS, VGG_IN),
    "tinynet": _chain_net("tinynet", TINYNET_SPECS, TINYNET_IN),
    "resnet50": Net(
        name="resnet50",
        in_shape=RESNET50_IN,
        init_params=resnet50_init_params,
        forward=resnet50_forward,
        layer_table=resnet50_layer_table,
    ),
}
