"""Pooling Pallas kernel — the paper's Pooling stage.

In FFCNN the pooling kernel sits behind the Conv kernel on an Altera
channel, consuming output pixels as they stream out so pooled layers
never round-trip through DDR.  Here the kernel is grid-parallel over
(N*C) channel tiles; within a tile the window maximum/average is a
static unrolled reduction over the kh*kw strided views — the same
line-buffer walk the FPGA does, expressed on a VMEM block.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv import _ceil_to, conv_out_shape

#: channels processed per grid step; 8 keeps the block under a VMEM bank
#: for the largest AlexNet/VGG feature maps (8*227*227*4 B ~ 1.6 MiB).
DEFAULT_TC = 8


def _pool_kernel(x_ref, o_ref, *, kh, kw, sh, sw, oh, ow, mode):
    x = x_ref[...]  # [TC, H, W]
    acc = None
    for i in range(kh):
        for j in range(kw):
            v = x[:, i : i + sh * oh : sh, j : j + sw * ow : sw]
            if acc is None:
                acc = v
            elif mode == "max":
                acc = jnp.maximum(acc, v)
            else:
                acc = acc + v
    if mode == "avg":
        acc = acc / float(kh * kw)
    o_ref[...] = acc


def pool2d(
    x: jnp.ndarray,
    kernel: Tuple[int, int],
    stride: Tuple[int, int],
    *,
    padding: Tuple[int, int] = (0, 0),
    mode: str = "max",
    tc: int = DEFAULT_TC,
    impl: str = "pallas",
    interpret: bool = True,
) -> jnp.ndarray:
    """Max/avg pooling, NCHW.  impl="jnp" uses lax.reduce_window."""
    if mode not in ("max", "avg"):
        raise ValueError(f"unknown pool mode {mode!r}")
    n, c, h, w = x.shape
    kh, kw = kernel
    sh, sw = stride
    ph, pw = padding
    oh, ow = conv_out_shape((h, w), kh, kw, stride, padding)

    if impl == "jnp":
        init = -jnp.inf if mode == "max" else 0.0
        op = jax.lax.max if mode == "max" else jax.lax.add
        out = jax.lax.reduce_window(
            x,
            jnp.array(init, x.dtype),
            op,
            (1, 1, kh, kw),
            (1, 1, sh, sw),
            [(0, 0), (0, 0), (ph, ph), (pw, pw)],
        )
        if mode == "avg":
            out = out / float(kh * kw)
        return out
    if impl != "pallas":
        raise ValueError(f"unknown pool impl {impl!r}")

    pad_val = -jnp.inf if mode == "max" else 0.0
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (ph, ph), (pw, pw)), constant_values=pad_val
    )
    hp, wp = h + 2 * ph, w + 2 * pw

    # Flatten (N, C) and pad the channel axis up to the tile size.
    nc = n * c
    ncp = _ceil_to(nc, tc)
    xf = xp.reshape(nc, hp, wp)
    if ncp != nc:
        xf = jnp.pad(xf, ((0, ncp - nc), (0, 0), (0, 0)))

    kern = functools.partial(
        _pool_kernel, kh=kh, kw=kw, sh=sh, sw=sw, oh=oh, ow=ow, mode=mode
    )
    out = pl.pallas_call(
        kern,
        grid=(ncp // tc,),
        in_specs=[pl.BlockSpec((tc, hp, wp), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tc, oh, ow), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ncp, oh, ow), x.dtype),
        interpret=interpret,
    )(xf)
    return out[:nc].reshape(n, c, oh, ow)


def global_avg_pool(x: jnp.ndarray, *, impl: str = "pallas", **kw) -> jnp.ndarray:
    """Global average pooling [N,C,H,W] -> [N,C] (ResNet head)."""
    n, c, h, w = x.shape
    if impl == "jnp":
        return jnp.mean(x, axis=(2, 3))
    out = pool2d(x, (h, w), (h, w), mode="avg", impl=impl, **kw)
    return out.reshape(n, c)
