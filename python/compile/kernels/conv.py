"""Flattened 1-D convolution (FFCNN Eq. 4) as a tiled Pallas GEMM.

The paper collapses the 5-deep convolution loop nest (Eq. 3) into a
2-level loop over ``(f_o, x_i in C*K*K)`` (Eq. 4) so the OpenCL compiler
can pipeline a multiplier-adder tree fed from a window buffer.  On a
TPU-shaped target the same flattening is exactly an im2col GEMM:

    W  : [F_o, C*K*K]           (reshaped filter bank)
    P  : [C*K*K, N*OH*OW]       (im2col patches, batch folded into cols)
    O  = W @ P (+ bias, ReLU)   (the MAC tree == one MXU tile per step)

Hardware-adaptation mapping (DESIGN.md §6):

- the paper's ``VEC_SIZE x LANE_NUM`` parallel DSP MACs  -> one
  ``(TM, TK) @ (TK, TN)`` MXU tile per grid step;
- the M20K window/weight buffers -> the VMEM blocks named by the
  BlockSpecs: a weight tile is revisited for every pixel tile (j), a
  patch tile for every filter tile (i) — the paper's data reuse;
- the channel-fused ReLU stage -> the epilogue in the final k step.

All kernels use ``interpret=True`` so they lower to plain HLO and run on
the CPU PJRT client (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  TM x TN is the output tile held in VMEM while the
# reduction streams through in TK chunks.  Chosen in the perf pass
# (EXPERIMENTS.md §Perf/L1): double-buffered fp32 tiles cost
# 2*4*(TM*TK + TK*TN + TM*TN) ≈ 3 MiB — comfortably inside a 16 MiB TPU
# VMEM — while large multiples of the 128-wide MXU edge amortize the
# per-grid-step dispatch that dominated the old (32,128,128) default
# (20x faster on AlexNet conv3 under the interpret-mode lowering).
DEFAULT_TM = 128
DEFAULT_TN = 512
DEFAULT_TK = 512


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad2(a: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    """Zero-pad a 2-D array up to [rows, cols]."""
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def _matmul_kernel(w_ref, p_ref, b_ref, o_ref, *, nk: int, relu: bool):
    """One grid step: accumulate a (TM,TN) output tile.

    Grid is (M/TM, N/TN, K/TK) with the reduction innermost; the output
    BlockSpec ignores the k index so the same VMEM tile accumulates
    across all k steps — the paper's multiplier-adder tree with its
    output buffer.  The epilogue (bias + ReLU) runs in the last k step,
    i.e. fused into the conv kernel exactly like the paper's
    channel-chained ReLU stage.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        w_ref[...], p_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...]  # b tile is [TM, 1], broadcasts
        if relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def matmul_bias_act(
    w: jnp.ndarray,
    p: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    relu: bool = False,
    tm: int = DEFAULT_TM,
    tn: int = DEFAULT_TN,
    tk: int = DEFAULT_TK,
    interpret: bool = True,
) -> jnp.ndarray:
    """``o = act(w @ p + b)`` via the tiled Pallas kernel.

    w: [M, K] filter bank, p: [K, N] patches, b: [M] bias (or None).
    Shapes are zero-padded up to tile multiples and the result sliced
    back, so any shape is accepted.
    """
    m, kdim = w.shape
    k2, n = p.shape
    if kdim != k2:
        raise ValueError(f"reduction mismatch: w[{m},{kdim}] @ p[{k2},{n}]")
    if b is None:
        b = jnp.zeros((m,), dtype=w.dtype)
    if b.shape != (m,):
        raise ValueError(f"bias shape {b.shape} != ({m},)")

    # Never tile wider than the (padded) problem.
    tm = min(tm, _ceil_to(m, 8))
    tn = min(tn, _ceil_to(n, 8))
    tk = min(tk, _ceil_to(kdim, 8))
    mp, np_, kp = _ceil_to(m, tm), _ceil_to(n, tn), _ceil_to(kdim, tk)

    wp = _pad2(w.astype(jnp.float32), mp, kp)
    pp = _pad2(p.astype(jnp.float32), kp, np_)
    bp = _pad2(b.astype(jnp.float32).reshape(m, 1), mp, 1)

    grid = (mp // tm, np_ // tn, kp // tk)
    kernel = functools.partial(_matmul_kernel, nk=grid[2], relu=relu)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),  # weight tile
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),  # patch tile
            pl.BlockSpec((tm, 1), lambda i, j, k: (i, 0)),  # bias tile
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(wp, pp, bp)
    return out[:m, :n]


def im2col(
    x: jnp.ndarray,
    kh: int,
    kw: int,
    stride: Tuple[int, int],
    padding: Tuple[int, int],
) -> jnp.ndarray:
    """Extract convolution patches: the paper's MemRd/DataIN kernel.

    x: [N, C, H, W]  ->  [N, C*kh*kw, OH, OW] with (C major, kh, kw)
    feature ordering, matching ``w.reshape(F, C*kh*kw)``.

    Implemented as kh*kw static strided slices — pure data movement that
    XLA fuses; this is the software analogue of the FPGA window/line
    buffer walking the padded input.
    """
    n, c, h, w = x.shape
    sh, sw = stride
    ph, pw = padding
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (w + 2 * pw - kw) // sw + 1
    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(xp[:, :, i : i + sh * oh : sh, j : j + sw * ow : sw])
    # [N, C, kh*kw, OH, OW] -> [N, C*kh*kw, OH, OW]
    patches = jnp.stack(cols, axis=2)
    return patches.reshape(n, c * kh * kw, oh, ow)


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    stride: Tuple[int, int] = (1, 1),
    padding: Tuple[int, int] = (0, 0),
    relu: bool = False,
    groups: int = 1,
    impl: str = "pallas",
    tm: int = DEFAULT_TM,
    tn: int = DEFAULT_TN,
    tk: int = DEFAULT_TK,
    interpret: bool = True,
) -> jnp.ndarray:
    """2-D convolution, NCHW / OIHW (w: [F, C/groups, kh, kw]).

    impl="pallas": the paper's path — im2col (MemRd) + tiled Pallas GEMM
    (Conv kernel) with fused bias/ReLU epilogue.
    impl="jnp": ``lax.conv_general_dilated`` — the fast XLA path used for
    full-resolution AOT artifacts (DESIGN.md §8); numerically checked
    against the pallas path and the naive oracle in pytest.

    ``groups=2`` reproduces the original two-column AlexNet convs — the
    variant whose 1.45 GOP count the paper's Table 1 GOPS figures imply.
    """
    n, c, h, wdim = x.shape
    f, cg, kh, kw = w.shape
    if c != cg * groups:
        raise ValueError(
            f"channel mismatch: x has {c}, w has {cg}*{groups} groups"
        )
    if f % groups:
        raise ValueError(f"filters {f} not divisible by groups {groups}")

    if impl == "jnp":
        out = jax.lax.conv_general_dilated(
            x,
            w,
            window_strides=stride,
            padding=[(padding[0], padding[0]), (padding[1], padding[1])],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=groups,
        )
        if b is not None:
            out = out + b.reshape(1, f, 1, 1)
        if relu:
            out = jnp.maximum(out, 0.0)
        return out

    if impl != "pallas":
        raise ValueError(f"unknown conv impl {impl!r}")

    if groups > 1:
        # Each group is an independent flattened GEMM — on the FPGA the
        # two AlexNet columns simply time-share the same Conv kernel.
        fg = f // groups
        outs = []
        for g in range(groups):
            bg = None if b is None else b[g * fg : (g + 1) * fg]
            outs.append(
                conv2d(
                    x[:, g * cg : (g + 1) * cg],
                    w[g * fg : (g + 1) * fg],
                    bg,
                    stride=stride,
                    padding=padding,
                    relu=relu,
                    groups=1,
                    impl=impl,
                    tm=tm,
                    tn=tn,
                    tk=tk,
                    interpret=interpret,
                )
            )
        return jnp.concatenate(outs, axis=1)

    patches = im2col(x, kh, kw, stride, padding)
    _, kflat, oh, ow = patches.shape
    # Fold batch into the GEMM column dimension: [K, N*OH*OW].  This is
    # the paper's batched flattening — one long 1-D MAC stream.
    pmat = patches.transpose(1, 0, 2, 3).reshape(kflat, n * oh * ow)
    omat = matmul_bias_act(
        w.reshape(f, kflat),
        pmat,
        b,
        relu=relu,
        tm=tm,
        tn=tn,
        tk=tk,
        interpret=interpret,
    )
    return omat.reshape(f, n, oh, ow).transpose(1, 0, 2, 3)


def conv_out_shape(
    hw: Tuple[int, int],
    kh: int,
    kw: int,
    stride: Tuple[int, int],
    padding: Tuple[int, int],
) -> Tuple[int, int]:
    """Output spatial size of a conv/pool window — shared shape logic."""
    h, w = hw
    oh = (h + 2 * padding[0] - kh) // stride[0] + 1
    ow = (w + 2 * padding[1] - kw) // stride[1] + 1
    return oh, ow
