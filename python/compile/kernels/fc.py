"""Fully-connected layer on the shared Pallas GEMM kernel.

The paper treats FC layers as matrix-vector products on the same Conv
engine (Eq. 4 with K=1 and the whole input vector as the reduction).
We reuse ``conv.matmul_bias_act`` so FC and Conv share one kernel, like
the single Conv OpenCL kernel serving both layer types in FFCNN.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .conv import matmul_bias_act


def fc(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    relu: bool = False,
    impl: str = "pallas",
    interpret: bool = True,
    **tiles,
) -> jnp.ndarray:
    """Dense layer: x [N, IN] @ w.T [IN, OUT] + b -> [N, OUT].

    w is stored [OUT, IN] (Caffe/torch convention), matching the
    flattened conv filter bank layout.
    """
    n, din = x.shape
    dout, din2 = w.shape
    if din != din2:
        raise ValueError(f"fc dim mismatch: x[{n},{din}] vs w[{dout},{din2}]")

    if impl == "jnp":
        out = x @ w.T
        if b is not None:
            out = out + b
        if relu:
            out = jnp.maximum(out, 0.0)
        return out
    if impl != "pallas":
        raise ValueError(f"unknown fc impl {impl!r}")

    # GEMM with batch on the columns: [OUT, IN] @ [IN, N] -> [OUT, N].
    out = matmul_bias_act(
        w, x.T, b, relu=relu, interpret=interpret, **tiles
    )
    return out.T
