"""Local response normalization Pallas kernel (FFCNN's LRN stage).

AlexNet-style across-channel LRN:

    out[c] = x[c] / (k + alpha/n * sum_{c' in window(c)} x[c']^2)^beta

In the FPGA pipeline LRN follows pooling on a channel (Fig. 2).  The
kernel is grid-parallel over spatial tiles; the full channel axis lives
in the block (C <= 512 for the nets here) so the cross-channel window is
a static unrolled sum over shifted views — the FPGA's shift-register
across feature maps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv import _ceil_to

#: spatial positions per grid step.
DEFAULT_TS = 512


def _lrn_kernel(x_ref, o_ref, *, n, k, alpha, beta, c):
    x = x_ref[...]  # [C, TS]
    sq = x * x
    half = n // 2
    # Zero-pad the channel axis; window sum as static shifted adds.
    sqp = jnp.pad(sq, ((half, half), (0, 0)))
    acc = jnp.zeros_like(x)
    for d in range(n):
        acc = acc + sqp[d : d + c, :]
    scale = (k + (alpha / n) * acc) ** beta
    o_ref[...] = x / scale


def lrn(
    x: jnp.ndarray,
    *,
    n: int = 5,
    k: float = 2.0,
    alpha: float = 1e-4,
    beta: float = 0.75,
    ts: int = DEFAULT_TS,
    impl: str = "pallas",
    interpret: bool = True,
) -> jnp.ndarray:
    """Across-channel LRN, NCHW.  Caffe-convention alpha (divided by n)."""
    nb, c, h, w = x.shape

    if impl == "jnp":
        half = n // 2
        sq = x * x
        sqp = jnp.pad(sq, ((0, 0), (half, half), (0, 0), (0, 0)))
        acc = jnp.zeros_like(x)
        for d in range(n):
            acc = acc + sqp[:, d : d + c, :, :]
        return x / (k + (alpha / n) * acc) ** beta
    if impl != "pallas":
        raise ValueError(f"unknown lrn impl {impl!r}")

    s = nb * h * w
    sp = _ceil_to(s, ts)
    # [C, N*H*W] layout puts the normalization axis contiguous in the
    # block and spatial positions on the lanes.
    xf = x.transpose(1, 0, 2, 3).reshape(c, s)
    if sp != s:
        xf = jnp.pad(xf, ((0, 0), (0, sp - s)))

    kern = functools.partial(
        _lrn_kernel, n=n, k=k, alpha=alpha, beta=beta, c=c
    )
    out = pl.pallas_call(
        kern,
        grid=(sp // ts,),
        in_specs=[pl.BlockSpec((c, ts), lambda i: (0, i))],
        out_specs=pl.BlockSpec((c, ts), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((c, sp), x.dtype),
        interpret=interpret,
    )(xf)
    return out[:, :s].reshape(c, nb, h, w).transpose(1, 0, 2, 3)
