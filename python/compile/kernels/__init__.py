"""L1 Pallas kernels for FFCNN.

Each kernel implements one stage of the paper's pipelined OpenCL
accelerator, re-thought for a TPU-style memory hierarchy (see
DESIGN.md §6 Hardware Adaptation):

- ``conv``    — the paper's flattened 1-D convolution (Eq. 4) as an
                im2col GEMM with a fused bias/ReLU epilogue.  The
                ``VEC_SIZE x LANE_NUM`` DSP multiplier-adder tree maps to
                one MXU matmul tile; the M20K window buffer maps to the
                VMEM BlockSpec schedule.
- ``pool``    — max/average pooling (the paper's Pooling kernel).
- ``lrn``     — local response normalization (AlexNet).
- ``fc``      — dense layers as GEMM on the same matmul kernel.
- ``ref``     — pure-jnp oracles, independent code paths used by pytest.

All pallas_calls run with ``interpret=True`` so they lower to plain HLO
executable on the CPU PJRT client (real-TPU lowering emits Mosaic
custom-calls the CPU plugin cannot run).
"""

from . import conv, eltwise, fc, lrn, pool, ref  # noqa: F401
