"""Pure-jnp correctness oracles — the role Caffe plays in the paper.

Every oracle takes a deliberately *different* code path from both the
pallas kernels and the impl="jnp" fast paths:

- ``conv2d_ref``  — explicit gather of shifted views + einsum (no
  lax.conv, no pallas GEMM);
- ``pool2d_ref``  — python loop over output pixels with window slices;
- ``lrn_ref``     — direct formula with a python channel loop;
- ``fc_ref``      — einsum.

pytest asserts allclose between kernel and oracle across shape sweeps
(hypothesis) — this is the build-time functional-correctness gate.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def conv2d_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    stride: Tuple[int, int] = (1, 1),
    padding: Tuple[int, int] = (0, 0),
    relu: bool = False,
    groups: int = 1,
) -> jnp.ndarray:
    """Naive convolution: shifted-view gather + einsum, NCHW/OIHW."""
    if groups > 1:
        f, cg = w.shape[0], w.shape[1]
        fg = f // groups
        outs = []
        for g in range(groups):
            bg = None if b is None else b[g * fg : (g + 1) * fg]
            outs.append(
                conv2d_ref(
                    x[:, g * cg : (g + 1) * cg],
                    w[g * fg : (g + 1) * fg],
                    bg,
                    stride=stride,
                    padding=padding,
                    relu=relu,
                )
            )
        return jnp.concatenate(outs, axis=1)
    n, c, h, wd = x.shape
    f, c2, kh, kw = w.shape
    assert c == c2, (c, c2)
    sh, sw = stride
    ph, pw = padding
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (wd + 2 * pw - kw) // sw + 1
    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    acc = jnp.zeros((n, f, oh, ow), dtype=jnp.float32)
    for i in range(kh):
        for j in range(kw):
            v = xp[:, :, i : i + sh * oh : sh, j : j + sw * ow : sw]
            acc = acc + jnp.einsum("nchw,fc->nfhw", v, w[:, :, i, j])
    if b is not None:
        acc = acc + b.reshape(1, f, 1, 1)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    return acc


def pool2d_ref(
    x: jnp.ndarray,
    kernel: Tuple[int, int],
    stride: Tuple[int, int],
    *,
    padding: Tuple[int, int] = (0, 0),
    mode: str = "max",
) -> jnp.ndarray:
    """Naive pooling: python loop over output pixels."""
    n, c, h, wd = x.shape
    kh, kw = kernel
    sh, sw = stride
    ph, pw = padding
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (wd + 2 * pw - kw) // sw + 1
    pad_val = -jnp.inf if mode == "max" else 0.0
    xp = jnp.pad(
        x, ((0, 0), (0, 0), (ph, ph), (pw, pw)), constant_values=pad_val
    )
    rows = []
    for oy in range(oh):
        cols = []
        for ox in range(ow):
            win = xp[:, :, oy * sh : oy * sh + kh, ox * sw : ox * sw + kw]
            if mode == "max":
                cols.append(jnp.max(win, axis=(2, 3)))
            else:
                cols.append(jnp.mean(win, axis=(2, 3)))
        rows.append(jnp.stack(cols, axis=-1))
    return jnp.stack(rows, axis=-2)


def lrn_ref(
    x: jnp.ndarray,
    *,
    n: int = 5,
    k: float = 2.0,
    alpha: float = 1e-4,
    beta: float = 0.75,
) -> jnp.ndarray:
    """Naive across-channel LRN with a python channel loop."""
    _, c, _, _ = x.shape
    half = n // 2
    outs = []
    for ci in range(c):
        lo, hi = max(0, ci - half), min(c, ci + half + 1)
        s = jnp.sum(x[:, lo:hi, :, :] ** 2, axis=1)
        outs.append(x[:, ci, :, :] / (k + (alpha / n) * s) ** beta)
    return jnp.stack(outs, axis=1)


def fc_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    relu: bool = False,
) -> jnp.ndarray:
    """Naive dense layer via einsum."""
    out = jnp.einsum("ni,oi->no", x, w)
    if b is not None:
        out = out + b
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def softmax_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable softmax over the last axis."""
    z = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)
