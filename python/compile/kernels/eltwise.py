"""Elementwise shortcut-add (+ ReLU) Pallas kernel.

ResNet's eltwise layers are the one op class in the paper's Table-1
networks that is neither conv/FC nor pooling/LRN; on the FPGA they run
on a small vector adder fed by two channels (the block output stream
and the buffered shortcut).  Here: grid over flat tiles, one fused
add(+ReLU) per block — used by ``nets.resnet50_forward`` when
``impl="pallas"`` so the whole residual path stays on L1 kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv import _ceil_to

#: elements per grid step (one VMEM lane-block of fp32).
DEFAULT_TE = 64 * 1024


def _eltwise_kernel(a_ref, b_ref, o_ref, *, relu):
    s = a_ref[...] + b_ref[...]
    if relu:
        s = jnp.maximum(s, 0.0)
    o_ref[...] = s


def add(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    relu: bool = False,
    te: int = DEFAULT_TE,
    impl: str = "pallas",
    interpret: bool = True,
) -> jnp.ndarray:
    """``relu(a + b)`` elementwise; shapes must match exactly."""
    if a.shape != b.shape:
        raise ValueError(f"eltwise shape mismatch: {a.shape} vs {b.shape}")
    if impl == "jnp":
        s = a + b
        return jnp.maximum(s, 0.0) if relu else s
    if impl != "pallas":
        raise ValueError(f"unknown eltwise impl {impl!r}")

    shape = a.shape
    n = a.size
    te = min(te, _ceil_to(n, 8))
    npad = _ceil_to(n, te)
    af = jnp.pad(a.reshape(-1), (0, npad - n))
    bf = jnp.pad(b.reshape(-1), (0, npad - n))
    kern = functools.partial(_eltwise_kernel, relu=relu)
    out = pl.pallas_call(
        kern,
        grid=(npad // te,),
        in_specs=[
            pl.BlockSpec((te,), lambda i: (i,)),
            pl.BlockSpec((te,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((te,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), a.dtype),
        interpret=interpret,
    )(af, bf)
    return out[:n].reshape(shape)
